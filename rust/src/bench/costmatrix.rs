//! Bang-for-the-buck instance-cost matrix: kernel class × QP memory
//! tier × shard count, priced against [`crate::cost::pricing`].
//!
//! The load engine ([`super::load`]) answers "what happens as offered
//! load rises?" at one fixed deployment shape. This sweep holds the
//! workload fixed and varies the *deployment*: which scan kernel class
//! the QP fleet is modeled to run, how much memory (and therefore
//! Lambda vCPU — see [`crate::cost::compute`]) each QP gets, and how
//! many QP shard functions each request scatters over. Every
//! configuration runs the same seeded open-loop workload points and
//! reports modeled p99 latency plus deterministic cost per 1000
//! queries; per workload point the sweep then names
//!
//! * the **cheapest configuration meeting the p99 SLO** — the
//!   provisioning answer ("what do I deploy?"), and
//! * the **fastest configuration per dollar** (minimum p99 × cost
//!   product) — the efficiency frontier point, which can differ when a
//!   config undercuts the SLO winner on latency for slightly more money.
//!
//! The kernel axis uses [`ComputeModel`]'s *what-if* override
//! (`kernel: Some(class)`), never the host's real engine: scan results
//! are bit-identical across kernel classes, so the matrix — including
//! its avx512 rows — is a property of the model and the seed, not of
//! the build machine. A CI scalar host and an AVX-512 workstation emit
//! byte-identical `BENCH_costmatrix.json` documents.
//!
//! # `BENCH_costmatrix.json` schema
//!
//! ```json
//! {
//!   "bench": "costmatrix",
//!   "profile": "test", "n": 3000, "queries": 48, "seed": 42,
//!   "slo_p99_ms": 250.0, "scalar_rows_per_s": 2000000.0,
//!   "max_containers": 4,
//!   "rows": [
//!     { "kernel": "avx512", "memory_mb": 1770, "qp_shards": 3,
//!       "offered_qps": 25, "p99_ms": 41.2, "mean_ms": 18.3,
//!       "achieved_qps": 24.8, "cold_starts": 9,
//!       "cost_per_1k_queries": 0.0034, "p99_cost_product": 0.14 } ],
//!   "picks": [
//!     { "offered_qps": 25,
//!       "cheapest_within_slo": { "kernel": "scalar", "memory_mb": 886,
//!                                "qp_shards": 1, ... } | null,
//!       "best_latency_per_dollar": { ... } } ]
//! }
//! ```
//!
//! `rows` is ordered kernel-major, then memory tier, then shard count,
//! then offered QPS — a deterministic order for digest-style diffing.
//! `cheapest_within_slo` is `null` when no configuration meets the SLO
//! at that load point (the sweep's honest "scale up or relax the SLO"
//! signal).

use crate::bench::load::{run_point, ArrivalProfile, LoadOptions, LoadPoint};
use crate::bench::{Env, EnvOptions};
use crate::cost::compute::ComputeModel;
use crate::osq::simd::KernelKind;
use crate::util::json::Json;

/// One deployment configuration on the matrix.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MatrixConfig {
    /// modeled kernel class (compute-model what-if, not the host engine)
    pub kernel: KernelKind,
    /// QP / QP-shard memory tier in MB (the vCPU axis)
    pub memory_mb: u32,
    /// fixed QP shard fan-out per partition (1 = no scatter)
    pub qp_shards: usize,
}

/// One measured (configuration, workload point) cell.
#[derive(Clone, Debug)]
pub struct MatrixRow {
    pub config: MatrixConfig,
    pub offered_qps: f64,
    pub p99_ms: f64,
    pub mean_ms: f64,
    pub achieved_qps: f64,
    pub cold_starts: u64,
    pub cost_per_1k_queries: f64,
}

impl MatrixRow {
    /// p99 × cost product: lower = more latency per dollar. The
    /// "fastest per dollar" pick minimizes this.
    pub fn p99_cost_product(&self) -> f64 {
        self.p99_ms * self.cost_per_1k_queries
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("kernel", Json::str(self.config.kernel.name())),
            ("memory_mb", Json::num(self.config.memory_mb as f64)),
            ("qp_shards", Json::num(self.config.qp_shards as f64)),
            ("offered_qps", Json::num(self.offered_qps)),
            ("p99_ms", Json::num(self.p99_ms)),
            ("mean_ms", Json::num(self.mean_ms)),
            ("achieved_qps", Json::num(self.achieved_qps)),
            ("cold_starts", Json::num(self.cold_starts as f64)),
            ("cost_per_1k_queries", Json::num(self.cost_per_1k_queries)),
            ("p99_cost_product", Json::num(self.p99_cost_product())),
        ])
    }
}

/// Matrix axes + workload knobs on top of an [`EnvOptions`] base.
#[derive(Clone, Debug)]
pub struct CostMatrixOptions {
    /// kernel-class axis (modeled; host-independent)
    pub kernels: Vec<KernelKind>,
    /// QP memory tiers in MB (the Lambda vCPU axis)
    pub memory_tiers_mb: Vec<u32>,
    /// fixed QP shard counts
    pub shards: Vec<usize>,
    /// offered-QPS workload points, ascending
    pub qps: Vec<f64>,
    /// the p99 latency SLO configurations must meet (modeled ms)
    pub slo_p99_ms: f64,
    /// modeled scalar scan rate anchoring the compute model (rows/s at
    /// one vCPU); see [`crate::cost::compute::DEFAULT_SCALAR_ROWS_PER_S`]
    pub scalar_rows_per_s: f64,
    /// fleet cap per function for the open-loop points
    pub max_containers: usize,
    /// arrival-process seed
    pub seed: u64,
}

impl Default for CostMatrixOptions {
    fn default() -> Self {
        Self {
            kernels: vec![KernelKind::Scalar, KernelKind::Avx2, KernelKind::Avx512],
            memory_tiers_mb: vec![886, 1770, 3538],
            shards: vec![1, 3],
            qps: vec![25.0, 100.0],
            slo_p99_ms: 250.0,
            scalar_rows_per_s: crate::cost::compute::DEFAULT_SCALAR_ROWS_PER_S,
            max_containers: 4,
            seed: 42,
        }
    }
}

/// Per-workload-point winners over a set of measured rows.
#[derive(Clone, Debug)]
pub struct PointPicks {
    pub offered_qps: f64,
    /// cheapest row with `p99_ms <= slo_p99_ms` (None: nothing meets it)
    pub cheapest_within_slo: Option<MatrixRow>,
    /// row minimizing the p99 × cost product
    pub best_latency_per_dollar: Option<MatrixRow>,
}

/// Select both winners for one offered-QPS point. Pure selection logic
/// over already-measured rows, split out so tests can pin it without
/// running environments. Ties break toward the earlier row, i.e. the
/// deterministic matrix order.
pub fn pick_for_point(rows: &[MatrixRow], offered_qps: f64, slo_p99_ms: f64) -> PointPicks {
    let at_point: Vec<&MatrixRow> =
        rows.iter().filter(|r| r.offered_qps == offered_qps).collect();
    let cheapest_within_slo = at_point
        .iter()
        .filter(|r| r.p99_ms <= slo_p99_ms)
        .min_by(|a, b| a.cost_per_1k_queries.total_cmp(&b.cost_per_1k_queries))
        .map(|r| (*r).clone());
    let best_latency_per_dollar = at_point
        .iter()
        .min_by(|a, b| a.p99_cost_product().total_cmp(&b.p99_cost_product()))
        .map(|r| (*r).clone());
    PointPicks { offered_qps, cheapest_within_slo, best_latency_per_dollar }
}

/// Build the fresh environment for one matrix configuration: fleet mode,
/// compute model enabled at the config's what-if kernel class, QP memory
/// pinned to the tier, fixed shard fan-out.
fn config_env(base: &EnvOptions, cfg: MatrixConfig, opts: &CostMatrixOptions) -> Env {
    let mut env_opts = base.clone();
    env_opts.virtual_pools = true;
    env_opts.max_containers = opts.max_containers;
    env_opts.compute =
        ComputeModel { scalar_rows_per_s: opts.scalar_rows_per_s, kernel: Some(cfg.kernel) };
    env_opts.memory_qp_mb = Some(cfg.memory_mb);
    env_opts.qp_sharding = if cfg.qp_shards <= 1 {
        crate::coordinator::QpSharding::Off
    } else {
        crate::coordinator::QpSharding::Fixed(cfg.qp_shards)
    };
    let mut env = Env::setup(&env_opts);
    super::load::configure_for_load(&mut env);
    env
}

/// The assembled sweep: every measured cell plus per-point winners and
/// the `BENCH_costmatrix.json` document.
pub struct CostMatrixOutput {
    pub rows: Vec<MatrixRow>,
    pub picks: Vec<PointPicks>,
    pub json: Json,
}

/// Run the full matrix (see the module docs for the emitted schema).
/// Each (configuration, QPS) cell runs on a fresh environment — fresh
/// ledger, fresh fleet — so cells are independent and the sweep order
/// cannot leak state; rows come out kernel-major, then tier, then
/// shards, then QPS.
pub fn run_matrix(base: &EnvOptions, opts: &CostMatrixOptions) -> CostMatrixOutput {
    // open loop through the default DES scheduler (dispatch-identical
    // to the retired serial engine, so every cell's digest is unchanged)
    let load_opts = LoadOptions {
        qps: opts.qps.clone(),
        fuse_window_ms: 0.0,
        max_containers: opts.max_containers,
        arrival: ArrivalProfile::Poisson,
        seed: opts.seed,
        ..LoadOptions::default()
    };
    let mut rows = Vec::new();
    for &kernel in &opts.kernels {
        for &memory_mb in &opts.memory_tiers_mb {
            for &qp_shards in &opts.shards {
                let cfg = MatrixConfig { kernel, memory_mb, qp_shards };
                for &qps in &opts.qps {
                    let env = config_env(base, cfg, opts);
                    let p: LoadPoint = run_point(&env, qps, &load_opts).stats;
                    rows.push(MatrixRow {
                        config: cfg,
                        offered_qps: qps,
                        p99_ms: p.p99_ms,
                        mean_ms: p.mean_ms,
                        achieved_qps: p.achieved_qps,
                        cold_starts: p.cold_starts,
                        cost_per_1k_queries: p.cost_per_1k_queries,
                    });
                }
            }
        }
    }
    let picks: Vec<PointPicks> =
        opts.qps.iter().map(|&q| pick_for_point(&rows, q, opts.slo_p99_ms)).collect();
    let pick_json = |r: &Option<MatrixRow>| match r {
        Some(r) => r.to_json(),
        None => Json::Null,
    };
    let json = Json::obj(vec![
        ("bench", Json::str("costmatrix")),
        ("profile", Json::str(base.profile)),
        ("n", Json::num(base.n as f64)),
        ("queries", Json::num(base.n_queries as f64)),
        ("seed", Json::num(opts.seed as f64)),
        ("slo_p99_ms", Json::num(opts.slo_p99_ms)),
        ("scalar_rows_per_s", Json::num(opts.scalar_rows_per_s)),
        ("max_containers", Json::num(opts.max_containers as f64)),
        ("rows", Json::Arr(rows.iter().map(|r| r.to_json()).collect())),
        (
            "picks",
            Json::Arr(
                picks
                    .iter()
                    .map(|p| {
                        Json::obj(vec![
                            ("offered_qps", Json::num(p.offered_qps)),
                            ("cheapest_within_slo", pick_json(&p.cheapest_within_slo)),
                            ("best_latency_per_dollar", pick_json(&p.best_latency_per_dollar)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    CostMatrixOutput { rows, picks, json }
}

/// Fixed-width table line for one matrix row (CLI / bench output).
pub fn row_line(r: &MatrixRow) -> String {
    format!(
        "{:<8} {:>7} {:>7} {:>9.1} {:>9.2} {:>9.2} {:>6} {:>12.6} {:>12.4}",
        r.config.kernel.name(),
        r.config.memory_mb,
        r.config.qp_shards,
        r.offered_qps,
        r.p99_ms,
        r.mean_ms,
        r.cold_starts,
        r.cost_per_1k_queries,
        r.p99_cost_product(),
    )
}

/// Header matching [`row_line`].
pub fn row_header() -> String {
    format!(
        "{:<8} {:>7} {:>7} {:>9} {:>9} {:>9} {:>6} {:>12} {:>12}",
        "kernel", "mem", "shards", "offered", "p99(ms)", "mean(ms)", "cold", "$/1k", "p99x$"
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(kernel: KernelKind, mem: u32, qps: f64, p99: f64, cost: f64) -> MatrixRow {
        MatrixRow {
            config: MatrixConfig { kernel, memory_mb: mem, qp_shards: 1 },
            offered_qps: qps,
            p99_ms: p99,
            mean_ms: p99 / 2.0,
            achieved_qps: qps,
            cold_starts: 0,
            cost_per_1k_queries: cost,
        }
    }

    #[test]
    fn picks_cheapest_meeting_slo_and_best_product() {
        let rows = vec![
            // meets SLO, expensive
            row(KernelKind::Avx512, 3538, 25.0, 40.0, 0.010),
            // meets SLO, cheapest → cheapest_within_slo
            row(KernelKind::Scalar, 886, 25.0, 90.0, 0.002),
            // misses SLO but tiny product → best_latency_per_dollar can
            // still differ from the SLO winner
            row(KernelKind::Avx2, 1770, 25.0, 120.0, 0.001),
            // different workload point, must be ignored
            row(KernelKind::Scalar, 886, 100.0, 30.0, 0.0001),
        ];
        let p = pick_for_point(&rows, 25.0, 100.0);
        let slo = p.cheapest_within_slo.expect("two rows meet the SLO");
        assert_eq!(slo.config.kernel, KernelKind::Scalar);
        assert_eq!(slo.config.memory_mb, 886);
        let best = p.best_latency_per_dollar.expect("non-empty point");
        assert_eq!(best.config.kernel, KernelKind::Avx2, "min p99×cost is the avx2 row");
        // SLO impossible → honest null
        let strict = pick_for_point(&rows, 25.0, 10.0);
        assert!(strict.cheapest_within_slo.is_none());
        assert!(strict.best_latency_per_dollar.is_some());
    }

    #[test]
    fn matrix_runs_and_replays_byte_identically() {
        let base = EnvOptions {
            profile: "test",
            n: 1200,
            n_queries: 8,
            time_scale: 0.0,
            ..Default::default()
        };
        let opts = CostMatrixOptions {
            kernels: vec![KernelKind::Scalar, KernelKind::Avx512],
            memory_tiers_mb: vec![886, 3538],
            shards: vec![1],
            qps: vec![500.0],
            slo_p99_ms: 1e9, // everything qualifies: pin the pick exists
            scalar_rows_per_s: 1.0e5,
            max_containers: 2,
            seed: 7,
        };
        let a = run_matrix(&base, &opts);
        let b = run_matrix(&base, &opts);
        assert_eq!(a.rows.len(), 4);
        // same seed ⇒ byte-identical document (the replay criterion); the
        // kernel axis is modeled, so this holds on any host
        assert_eq!(a.json.to_string_pretty(), b.json.to_string_pretty());
        // the modeled kernel ladder must actually move latency: at equal
        // tier, the avx512 row's p99 is no worse than scalar's
        let p99 = |k: KernelKind, mem: u32| {
            a.rows
                .iter()
                .find(|r| r.config.kernel == k && r.config.memory_mb == mem)
                .expect("row present")
                .p99_ms
        };
        assert!(
            p99(KernelKind::Avx512, 886) <= p99(KernelKind::Scalar, 886),
            "modeled avx512 must not be slower than scalar at the same tier"
        );
        // and the memory axis must move cost: a bigger tier bills more
        // MB-seconds per query at the same kernel
        let cost = |k: KernelKind, mem: u32| {
            a.rows
                .iter()
                .find(|r| r.config.kernel == k && r.config.memory_mb == mem)
                .expect("row present")
                .cost_per_1k_queries
        };
        assert!(
            cost(KernelKind::Scalar, 3538) != cost(KernelKind::Scalar, 886),
            "memory tier must be visible in cost"
        );
        assert!(a.picks[0].cheapest_within_slo.is_some());
        assert!(a.picks[0].best_latency_per_dollar.is_some());
    }
}
