//! Shared experiment harness: dataset/system setup, measured runs and
//! report formatting used by `rust/benches/*` (one per paper
//! table/figure), the examples, and the CLI. The open-loop traffic
//! engine (seeded arrivals, fusion windows, QPS sweeps) lives in
//! [`load`].

pub mod costmatrix;
pub mod keepalive;
pub mod load;
pub mod resilience;

use std::sync::Arc;

use crate::baselines::server::{InstanceType, ServerRunner};
use crate::baselines::system_x::{SystemX, SystemXParams};
use crate::coordinator::{BuildOptions, SquashConfig, SquashSystem};
use crate::cost::pricing::Pricing;
use crate::cost::{CostLedger, CostReport};
use crate::data::ground_truth::{exact_batch, mean_recall};
use crate::data::profiles::{by_name, Profile};
use crate::data::synthetic::generate;
use crate::data::workload::{generate_workload, Query, WorkloadOptions};
use crate::data::Dataset;
use crate::cost::compute::ComputeModel;
use crate::faas::{FaasConfig, Platform};
use crate::osq::simd::{KernelKind, Kernels};
use crate::runtime::backend::{select_engine_with, ScanEngine, ScanParallelism};
use crate::runtime::Engine;
use crate::storage::{FileStore, ObjectStore, SimParams};
use crate::util::stats::LatencySummary;

/// Experiment environment parameters.
#[derive(Clone, Debug)]
pub struct EnvOptions {
    pub profile: &'static str,
    /// dataset size (0 = profile default)
    pub n: usize,
    pub n_queries: usize,
    pub selectivity: f64,
    /// latency fidelity: 1.0 = full modeled latencies (benches),
    /// 0.0 = no sleeping (unit tests)
    pub time_scale: f64,
    pub dre: bool,
    /// "native" | "scalar" | "xla" | "auto"
    pub backend: String,
    /// row sharding inside each QP scan (native backends)
    pub scan_parallelism: ScanParallelism,
    /// multi-function QP scatter (coordinator-level row sharding)
    pub qp_sharding: crate::coordinator::QpSharding,
    /// deterministic tail-latency / fault injection (`--chaos-seed`)
    pub chaos: crate::faas::ChaosConfig,
    /// straggler hedging for the QP scatter (`--hedge off|pN`)
    pub hedge: crate::coordinator::HedgePolicy,
    /// event-driven fleet mode: containers carry virtual-time `free_at`
    /// stamps and concurrent requests contend (`FaasConfig::virtual_pools`)
    pub virtual_pools: bool,
    /// fleet cap per function in fleet mode (0 = uncapped)
    pub max_containers: usize,
    /// per-attempt invocation timeout in modeled seconds (∞ = none)
    pub fn_timeout_s: f64,
    /// retry budget + backoff policy (`RetryPolicy::legacy()` = the
    /// pre-resilience immediate-retry loop)
    pub retry: crate::faas::resilience::RetryPolicy,
    /// per-function-pool circuit breaker (`BreakerConfig::off()` = none)
    pub breaker: crate::faas::resilience::BreakerConfig,
    /// end-to-end request deadline in modeled seconds (None = none)
    pub deadline_s: Option<f64>,
    /// deadline-aware admission at the CO (`--shed`): shed waves whose
    /// remaining budget cannot cover the warm-path estimate (inert
    /// without a finite deadline; see `SquashConfig::shed`)
    pub shed: bool,
    /// container keep-alive / prewarm policy (`NeverExpire` = the
    /// pre-policy platform; `--keepalive never|ttl:<s>|hybrid`)
    pub keepalive: crate::faas::keepalive::KeepAliveConfig,
    /// force a specific scan-kernel class (`--kernel`, errors if the
    /// host lacks the ISA); `None` = auto-detect (honours SQUASH_KERNEL)
    pub kernel: Option<KernelKind>,
    /// memory-tier-aware modeled scan compute (off by default — every
    /// pre-existing digest stays byte-identical)
    pub compute: ComputeModel,
    /// override the QP/QP-shard memory tier in MB (`None` = FaasConfig
    /// default); the costmatrix sweep's tier axis
    pub memory_qp_mb: Option<u32>,
    pub seed: u64,
}

impl Default for EnvOptions {
    fn default() -> Self {
        Self {
            profile: "sift",
            n: 0,
            n_queries: 1000,
            selectivity: 0.08,
            time_scale: 1.0,
            dre: true,
            backend: "native".to_string(),
            // all four knobs honour the CI environment overrides
            // (SQUASH_SCAN_THREADS / SQUASH_QP_SHARDS / SQUASH_CHAOS_SEED
            // / SQUASH_HEDGE) by default
            scan_parallelism: ScanParallelism::from_env().unwrap_or(ScanParallelism::Serial),
            qp_sharding: crate::coordinator::QpSharding::from_env()
                .unwrap_or(crate::coordinator::QpSharding::Off),
            chaos: crate::faas::ChaosConfig::from_env(),
            hedge: crate::coordinator::HedgePolicy::from_env()
                .unwrap_or(crate::coordinator::HedgePolicy::Off),
            virtual_pools: false,
            max_containers: 0,
            fn_timeout_s: f64::INFINITY,
            retry: crate::faas::resilience::RetryPolicy::legacy(),
            breaker: crate::faas::resilience::BreakerConfig::off(),
            deadline_s: None,
            // honours SQUASH_SHED (the CI knob for the shedding suite)
            shed: std::env::var("SQUASH_SHED").ok().is_some_and(|v| v == "1"),
            // honours SQUASH_KEEPALIVE (the CI knob for whole-suite runs)
            keepalive: crate::faas::keepalive::KeepAliveConfig::from_env(),
            kernel: None,
            // honours SQUASH_COMPUTE_RPS / SQUASH_COMPUTE_KERNEL
            compute: ComputeModel::from_env(),
            memory_qp_mb: None,
            seed: 42,
        }
    }
}

/// A fully deployed experiment environment.
pub struct Env {
    pub profile: &'static Profile,
    pub ds: Dataset,
    pub sys: SquashSystem,
    pub queries: Vec<Query>,
    pub platform: Arc<Platform>,
    pub ledger: Arc<CostLedger>,
    pub pricing: Pricing,
}

impl Env {
    /// Generate data, build + deploy SQUASH, generate the workload.
    pub fn setup(opts: &EnvOptions) -> Env {
        let profile = by_name(opts.profile).unwrap_or_else(|| panic!("profile {}", opts.profile));
        let ds = generate(profile, opts.n, opts.seed);
        let ledger = Arc::new(CostLedger::new());
        let params = SimParams { time_scale: opts.time_scale, ..Default::default() };
        let mut faas_cfg = FaasConfig {
            dre_enabled: opts.dre,
            chaos: opts.chaos,
            virtual_pools: opts.virtual_pools,
            max_containers: opts.max_containers,
            fn_timeout_s: opts.fn_timeout_s,
            retry: opts.retry,
            breaker: opts.breaker,
            keepalive: opts.keepalive.clone(),
            compute: opts.compute,
            ..Default::default()
        };
        if let Some(mb) = opts.memory_qp_mb {
            faas_cfg.memory_qp_mb = mb;
        }
        let platform = Arc::new(Platform::new(faas_cfg, params.clone(), ledger.clone()));
        let s3 = Arc::new(ObjectStore::new(params.clone(), ledger.clone()));
        let efs = Arc::new(FileStore::new(params, ledger.clone()));
        let pjrt_engine = Engine::load_default().ok().map(Arc::new);
        let kernels = match opts.kernel {
            Some(k) => Kernels::forced(k).unwrap_or_else(|e| panic!("--kernel: {e}")),
            None => Kernels::detect(),
        };
        let engine: Arc<dyn ScanEngine> =
            select_engine_with(&opts.backend, pjrt_engine, profile.d, opts.scan_parallelism, kernels);
        let mut cfg = SquashConfig::for_profile(profile);
        cfg.qp_shards = opts.qp_sharding;
        cfg.hedge = opts.hedge;
        cfg.deadline_s = opts.deadline_s;
        cfg.shed = opts.shed;
        let sys = SquashSystem::build(
            &ds,
            &BuildOptions::for_profile(profile),
            cfg,
            platform.clone(),
            s3,
            efs,
            engine,
        );
        let queries = generate_workload(
            &ds,
            &WorkloadOptions {
                n_queries: opts.n_queries,
                selectivity: opts.selectivity,
                ..Default::default()
            },
            opts.seed + 1,
        )
        .queries;
        Env { profile, ds, sys, queries, platform, ledger, pricing: Pricing::default() }
    }

    /// Reconfigure the query path (e.g. a different tree shape) in place.
    pub fn with_config(&mut self, f: impl FnOnce(&mut SquashConfig)) {
        // SystemCtx is shared behind an Arc; rebuild it with the new config
        let mut ctx = (*self.sys.ctx).clone_shallow();
        f(&mut ctx.cfg);
        self.sys.ctx = Arc::new(ctx);
    }
}

impl crate::coordinator::SystemCtx {
    /// Shallow clone (all fields are Arcs or small values).
    pub fn clone_shallow(&self) -> crate::coordinator::SystemCtx {
        crate::coordinator::SystemCtx {
            cfg: self.cfg.clone(),
            platform: self.platform.clone(),
            s3: self.s3.clone(),
            efs: self.efs.clone(),
            ledger: self.ledger.clone(),
            engine: self.engine.clone(),
            cache: self.cache.clone(),
            ds_name: self.ds_name.clone(),
            d: self.d,
            n_partitions: self.n_partitions,
            n_rows: self.n_rows,
            t: self.t,
        }
    }
}

/// One measured batch run.
#[derive(Clone, Debug)]
pub struct RunStats {
    pub label: String,
    pub queries: usize,
    pub wall_s: f64,
    pub qps: f64,
    pub latency: LatencySummary,
    pub cost: CostReport,
    pub cost_per_query: f64,
    pub recall: f64,
}

impl RunStats {
    pub fn header() -> String {
        format!(
            "{:<26} {:>7} {:>9} {:>9} {:>12} {:>14} {:>8}",
            "run", "queries", "wall(s)", "QPS", "p50(ms)", "$/query", "recall"
        )
    }
}

impl std::fmt::Display for RunStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<26} {:>7} {:>9.3} {:>9.1} {:>12.2} {:>14.9} {:>8.4}",
            self.label,
            self.queries,
            self.wall_s,
            self.qps,
            self.latency.p50 * 1e3,
            self.cost_per_query,
            self.recall
        )
    }
}

/// Run SQUASH on the env's workload and measure everything. `truth_k`
/// of 0 skips ground truth (fast sweeps).
pub fn measure_squash(env: &Env, label: &str, truth_k: usize) -> RunStats {
    let before = env.ledger.report(&env.pricing);
    let out = env.sys.run_batch(&env.queries);
    let after = env.ledger.report(&env.pricing);
    let cost = delta_report(&before, &after);
    let recall = if truth_k > 0 {
        let truth = exact_batch(&env.ds, &env.queries, crate::util::threadpool::num_cpus());
        mean_recall(&truth, &out.results, truth_k)
    } else {
        f64::NAN
    };
    // batch latency: the whole batch shares one CO round trip; per-query
    // p50 is approximated by the wall over concurrent waves
    let mut lat = crate::util::stats::LatencyRecorder::new();
    lat.record(out.wall_s);
    RunStats {
        label: label.to_string(),
        queries: env.queries.len(),
        wall_s: out.wall_s,
        qps: env.queries.len() as f64 / out.wall_s.max(1e-9),
        latency: lat.summary(),
        cost,
        cost_per_query: cost.total() / env.queries.len().max(1) as f64,
        recall,
    }
}

/// Itemized difference of two cumulative ledger snapshots.
pub fn delta_report(before: &CostReport, after: &CostReport) -> CostReport {
    CostReport {
        invocations: after.invocations - before.invocations,
        cold_starts: after.cold_starts - before.cold_starts,
        mb_seconds: after.mb_seconds - before.mb_seconds,
        s3_gets: after.s3_gets - before.s3_gets,
        efs_bytes: after.efs_bytes - before.efs_bytes,
        payload_bytes: after.payload_bytes - before.payload_bytes,
        c_invoc: after.c_invoc - before.c_invoc,
        c_run: after.c_run - before.c_run,
        c_s3: after.c_s3 - before.c_s3,
        c_efs: after.c_efs - before.c_efs,
    }
}

/// Deploy + measure System-X on the same dataset/workload.
pub fn measure_system_x(env: &Env, truth_k: usize) -> RunStats {
    let sx = SystemX::upsert(&env.ds, SystemXParams::default(), env.pricing.clone());
    let out = sx.run_batch(&env.queries);
    let recall = if truth_k > 0 {
        let truth = exact_batch(&env.ds, &env.queries, crate::util::threadpool::num_cpus());
        mean_recall(&truth, &out.results, truth_k)
    } else {
        f64::NAN
    };
    RunStats {
        label: "system-x".to_string(),
        queries: env.queries.len(),
        wall_s: out.wall_s,
        qps: env.queries.len() as f64 / out.wall_s.max(1e-9),
        latency: out.latency.summary(),
        cost: CostReport::default(),
        cost_per_query: out.total_cost / env.queries.len().max(1) as f64,
        recall,
    }
}

/// Build + measure a server baseline on the same dataset/workload.
pub fn measure_server(env: &Env, instance: InstanceType, truth_k: usize) -> RunStats {
    let cfg = SquashConfig::for_profile(env.profile);
    let server = ServerRunner::build(&env.ds, instance, cfg, env.profile.partitions);
    let out = server.run_batch(&env.queries);
    let recall = if truth_k > 0 {
        let truth = exact_batch(&env.ds, &env.queries, crate::util::threadpool::num_cpus());
        mean_recall(&truth, &out.results, truth_k)
    } else {
        f64::NAN
    };
    // provisioned cost amortized over this batch at full utilization is
    // not meaningful per query; Fig 8 uses the daily-cost model instead.
    RunStats {
        label: format!("server {}", instance.name()),
        queries: env.queries.len(),
        wall_s: out.wall_s,
        qps: env.queries.len() as f64 / out.wall_s.max(1e-9),
        latency: out.latency.summary(),
        cost: CostReport::default(),
        cost_per_query: 0.0,
        recall,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn env_setup_and_measure() {
        let opts = EnvOptions {
            profile: "test",
            n: 1500,
            n_queries: 10,
            time_scale: 0.0,
            ..Default::default()
        };
        let env = Env::setup(&opts);
        let stats = measure_squash(&env, "smoke", 10);
        assert_eq!(stats.queries, 10);
        assert!(stats.qps > 0.0);
        assert!(stats.recall > 0.5, "recall {}", stats.recall);
        assert!(stats.cost.invocations > 0);
        assert!(stats.cost_per_query > 0.0);
    }

    #[test]
    fn with_config_changes_tree() {
        let opts = EnvOptions {
            profile: "test",
            n: 800,
            n_queries: 4,
            time_scale: 0.0,
            ..Default::default()
        };
        let mut env = Env::setup(&opts);
        env.with_config(|c| c.tree = crate::coordinator::tree::TreeConfig::new(10, 1));
        let stats = measure_squash(&env, "tree10", 0);
        assert!(stats.recall.is_nan());
        assert!(stats.cost.invocations > 0);
    }
}
