//! Filtered brute-force ground truth and recall accounting
//! (paper §5.1: recall@k = |G ∩ R| / k with G the filter-satisfying true
//! nearest neighbors).

use crate::attrs::mask::naive_mask;
use crate::data::workload::Query;
use crate::data::Dataset;
use crate::osq::distance::top_k_smallest;
use crate::util::matrix::l2_sq;
use crate::util::threadpool::parallel_map;

/// Exact filtered top-k for one query (brute force over passing rows).
pub fn exact_top_k(ds: &Dataset, q: &Query) -> Vec<(u64, f32)> {
    let mask = naive_mask(&ds.attributes, &q.predicate);
    top_k_smallest(
        mask.iter_ones().map(|i| (i as u64, l2_sq(&q.vector, ds.vectors.row(i)))),
        q.k,
    )
}

/// Ground truth for a batch, computed in parallel.
pub fn exact_batch(ds: &Dataset, queries: &[Query], threads: usize) -> Vec<Vec<(u64, f32)>> {
    parallel_map(queries, threads, |_, q| exact_top_k(ds, q))
}

/// recall@k of retrieved ids vs ground-truth ids.
pub fn recall_at_k(truth: &[(u64, f32)], retrieved: &[(u64, f32)], k: usize) -> f64 {
    if k == 0 {
        return 1.0;
    }
    let gt: std::collections::HashSet<u64> = truth.iter().take(k).map(|&(i, _)| i).collect();
    if gt.is_empty() {
        // no vector satisfies the filter: define recall as 1 when the
        // system also returns nothing relevant
        return if retrieved.is_empty() { 1.0 } else { 1.0 };
    }
    let hits = retrieved.iter().take(k).filter(|&&(i, _)| gt.contains(&i)).count();
    // the paper divides by k; when fewer than k vectors pass globally,
    // divide by the achievable count so recall stays in [0, 1]
    hits as f64 / gt.len().min(k) as f64
}

/// Mean recall@k over a batch.
pub fn mean_recall(
    truth: &[Vec<(u64, f32)>],
    retrieved: &[Vec<(u64, f32)>],
    k: usize,
) -> f64 {
    assert_eq!(truth.len(), retrieved.len());
    if truth.is_empty() {
        return 1.0;
    }
    let total: f64 =
        truth.iter().zip(retrieved).map(|(t, r)| recall_at_k(t, r, k)).sum();
    total / truth.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::profiles::by_name;
    use crate::data::synthetic::generate;
    use crate::data::workload::{generate_workload, WorkloadOptions};

    #[test]
    fn exact_results_pass_filter_and_sorted() {
        let ds = generate(by_name("test").unwrap(), 3000, 1);
        let w = generate_workload(&ds, &WorkloadOptions { n_queries: 10, ..Default::default() }, 2);
        for q in &w.queries {
            let top = exact_top_k(&ds, q);
            assert!(top.len() <= q.k);
            for win in top.windows(2) {
                assert!(win[0].1 <= win[1].1);
            }
            for &(id, dist) in &top {
                assert!(q.predicate.eval(&ds.attributes[id as usize]));
                let want = l2_sq(&q.vector, ds.vectors.row(id as usize));
                assert!((dist - want).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn batch_matches_single() {
        let ds = generate(by_name("test").unwrap(), 1000, 3);
        let w = generate_workload(&ds, &WorkloadOptions { n_queries: 8, ..Default::default() }, 4);
        let batch = exact_batch(&ds, &w.queries, 4);
        for (q, b) in w.queries.iter().zip(&batch) {
            assert_eq!(&exact_top_k(&ds, q), b);
        }
    }

    #[test]
    fn recall_accounting() {
        let truth = vec![(1u64, 0.1f32), (2, 0.2), (3, 0.3)];
        let perfect = truth.clone();
        assert_eq!(recall_at_k(&truth, &perfect, 3), 1.0);
        let partial = vec![(1u64, 0.1f32), (9, 0.15), (3, 0.3)];
        assert!((recall_at_k(&truth, &partial, 3) - 2.0 / 3.0).abs() < 1e-12);
        let empty: Vec<(u64, f32)> = vec![];
        assert_eq!(recall_at_k(&empty, &empty, 5), 1.0);
    }

    #[test]
    fn recall_with_fewer_than_k_passing() {
        // only 2 vectors pass the filter globally; returning both = 1.0
        let truth = vec![(4u64, 0.5f32), (7, 0.9)];
        let got = vec![(4u64, 0.5f32), (7, 0.9)];
        assert_eq!(recall_at_k(&truth, &got, 10), 1.0);
    }
}
