//! Dataset profiles mirroring the paper's Table 2, with scaled default
//! sizes for the offline reproduction (full sizes are a config change).

/// A dataset profile (paper Table 2 row).
#[derive(Clone, Debug)]
pub struct Profile {
    pub name: &'static str,
    /// dimensionality (matches the paper exactly)
    pub d: usize,
    /// paper's N
    pub paper_n: usize,
    /// default N for the reproduction runs
    pub default_n: usize,
    /// per-vector bit budget b = 4 * d (paper Table 2)
    pub bit_budget: usize,
    /// partitions P (paper §5.3: 10 for 1M-scale, 20 for 10M-scale)
    pub partitions: usize,
    /// paper's tuned centroid-distance threshold T (§5.3)
    pub t_threshold: f32,
    /// Hamming cut keep-fraction (paper's H_perc = 10 => 0.10, tuned per
    /// dataset; low-d profiles need a wider cut — 1-bit signatures get
    /// coarser as d shrinks)
    pub h_keep: f64,
    /// fine-tuning ratio R (§2.4.5): refine R*k candidates. Paper uses 2
    /// on the real datasets; the synthetic GIST-like profile needs 4 (its
    /// 4-bit LB ordering is weaker at d=960 than on real GIST).
    pub refine_ratio: usize,
    /// clusters in the synthetic mixture (difficulty knob; higher LID
    /// datasets get more, tighter clusters)
    pub clusters: usize,
    /// within-cluster noise scale relative to center spread
    pub noise: f32,
    /// number of attributes A (paper §5.1: 4)
    pub n_attrs: usize,
}

/// The paper's four datasets plus a tiny CI profile (d=16 matches the
/// `test` XLA artifact configuration).
pub const PROFILES: &[Profile] = &[
    Profile {
        name: "test",
        d: 16,
        paper_n: 0,
        default_n: 4_000,
        bit_budget: 64,
        partitions: 4,
        t_threshold: 1.15,
        h_keep: 0.60,
        refine_ratio: 2,
        clusters: 16,
        noise: 0.35,
        n_attrs: 4,
    },
    Profile {
        name: "sift",
        d: 128,
        paper_n: 1_000_000,
        default_n: 100_000,
        bit_budget: 512,
        partitions: 10,
        t_threshold: 1.15,
        h_keep: 0.15,
        refine_ratio: 2,
        clusters: 64,
        noise: 0.35,
        n_attrs: 4,
    },
    Profile {
        name: "gist",
        d: 960,
        paper_n: 1_000_000,
        default_n: 20_000,
        bit_budget: 3840,
        partitions: 10,
        t_threshold: 1.2,
        h_keep: 0.25,
        refine_ratio: 4,
        clusters: 32,
        noise: 0.5, // higher LID (29.1): noisier, less separable
        n_attrs: 4,
    },
    Profile {
        name: "sift10m",
        d: 128,
        paper_n: 10_000_000,
        default_n: 200_000,
        bit_budget: 512,
        partitions: 20,
        t_threshold: 1.15,
        h_keep: 0.15,
        refine_ratio: 2,
        clusters: 64,
        noise: 0.35,
        n_attrs: 4,
    },
    Profile {
        name: "deep",
        d: 96,
        paper_n: 10_000_000,
        default_n: 200_000,
        bit_budget: 384,
        partitions: 20,
        t_threshold: 1.13,
        h_keep: 0.30,
        refine_ratio: 2,
        clusters: 80,
        noise: 0.3, // lowest LID (10.2): cleanest clusters
        n_attrs: 4,
    },
];

/// Look up a profile by name.
pub fn by_name(name: &str) -> Option<&'static Profile> {
    PROFILES.iter().find(|p| p.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_table2_dimensions() {
        assert_eq!(by_name("sift").unwrap().d, 128);
        assert_eq!(by_name("gist").unwrap().d, 960);
        assert_eq!(by_name("sift10m").unwrap().d, 128);
        assert_eq!(by_name("deep").unwrap().d, 96);
    }

    #[test]
    fn bit_budget_is_4d() {
        for p in PROFILES {
            assert_eq!(p.bit_budget, 4 * p.d, "{}", p.name);
        }
    }

    #[test]
    fn unknown_profile() {
        assert!(by_name("nope").is_none());
    }
}
