//! Hybrid-query workload generation (paper §5.1): 1000 queries per run,
//! each with a vector (a perturbed database vector, the standard
//! benchmark construction) and a multi-attribute predicate with a target
//! joint selectivity of ~8%.

use crate::attrs::predicate::{Conjunction, Op, Predicate};
use crate::data::attributes::{CATEGORICAL_CARD, NUMERIC_GRID};
use crate::data::Dataset;
use crate::util::rng::Rng;

/// One hybrid query: vector + predicate + top-k limit.
#[derive(Clone, Debug)]
pub struct Query {
    pub vector: Vec<f32>,
    pub predicate: Predicate,
    pub k: usize,
}

/// A batch workload.
#[derive(Clone, Debug)]
pub struct Workload {
    pub queries: Vec<Query>,
}

/// Workload generation options.
#[derive(Clone, Debug)]
pub struct WorkloadOptions {
    pub n_queries: usize,
    pub k: usize,
    /// target joint selectivity (paper: 0.08). 1.0 => match-all (pure ANN)
    pub selectivity: f64,
    /// noise added to the seed database vector
    pub query_noise: f32,
}

impl Default for WorkloadOptions {
    fn default() -> Self {
        Self { n_queries: 1000, k: 10, selectivity: 0.08, query_noise: 0.1 }
    }
}

/// Generate a workload over a dataset.
///
/// Per-attribute range predicates are sized so their product hits the
/// joint selectivity target: with A attributes each gets selectivity
/// `s^(1/A)` — numeric attrs get a random BETWEEN window of that width on
/// the grid, the categorical attr gets an equality-set via BETWEEN over
/// category codes (contiguous ids ≈ fraction of categories).
pub fn generate_workload(ds: &Dataset, opts: &WorkloadOptions, seed: u64) -> Workload {
    let mut rng = Rng::new(seed ^ 0x574C_4F41);
    let a = ds.n_attrs();
    let queries = (0..opts.n_queries)
        .map(|_| {
            // query vector: perturbed database row
            let base = rng.gen_range(ds.n());
            let vector: Vec<f32> = ds
                .vectors
                .row(base)
                .iter()
                .map(|&v| v + rng.normal() * opts.query_noise)
                .collect();
            let predicate = if opts.selectivity >= 1.0 || a == 0 {
                Predicate::match_all(a)
            } else {
                let per_attr = (opts.selectivity.powf(1.0 / a as f64)).clamp(0.0, 1.0);
                let mut c = Conjunction::all_pass(a);
                for attr in 0..a {
                    let op = if attr + 1 == a && a > 1 {
                        // categorical: contiguous id range covering per_attr
                        let width = ((CATEGORICAL_CARD as f64 * per_attr).round() as usize)
                            .clamp(1, CATEGORICAL_CARD);
                        let lo = rng.gen_range(CATEGORICAL_CARD - width + 1);
                        Op::Between(lo as f32, (lo + width - 1) as f32)
                    } else {
                        let width = ((NUMERIC_GRID as f64 * per_attr).round() as usize)
                            .clamp(1, NUMERIC_GRID);
                        let lo = rng.gen_range(NUMERIC_GRID - width + 1);
                        Op::Between(lo as f32, (lo + width - 1) as f32)
                    };
                    c = c.with(attr, op);
                }
                Predicate::single(c)
            };
            Query { vector, predicate, k: opts.k }
        })
        .collect();
    Workload { queries }
}

/// Arrival models for the cost experiments (paper §5.4: "queries arrive
/// at uniform intervals over a 24 hour period").
#[derive(Clone, Copy, Debug)]
pub enum ArrivalModel {
    /// `volume` queries spread evenly over `period_s` seconds
    Uniform { volume: u64, period_s: f64 },
}

impl ArrivalModel {
    /// Mean inter-arrival gap in seconds.
    pub fn mean_gap_s(&self) -> f64 {
        match *self {
            ArrivalModel::Uniform { volume, period_s } => period_s / volume.max(1) as f64,
        }
    }

    pub fn volume(&self) -> u64 {
        match *self {
            ArrivalModel::Uniform { volume, .. } => volume,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attrs::mask::naive_mask;
    use crate::data::profiles::by_name;
    use crate::data::synthetic::generate;

    #[test]
    fn workload_shapes() {
        let ds = generate(by_name("test").unwrap(), 2000, 1);
        let w = generate_workload(&ds, &WorkloadOptions::default(), 2);
        assert_eq!(w.queries.len(), 1000);
        assert!(w.queries.iter().all(|q| q.vector.len() == 16 && q.k == 10));
    }

    #[test]
    fn selectivity_near_target() {
        let ds = generate(by_name("test").unwrap(), 20_000, 3);
        let opts = WorkloadOptions { n_queries: 60, ..Default::default() };
        let w = generate_workload(&ds, &opts, 4);
        let sels: Vec<f64> = w
            .queries
            .iter()
            .map(|q| naive_mask(&ds.attributes, &q.predicate).count_ones() as f64 / 20_000.0)
            .collect();
        let mean = crate::util::stats::mean(&sels);
        assert!((mean - 0.08).abs() < 0.03, "mean selectivity {mean}");
        // every query admits at least a few candidates
        assert!(sels.iter().all(|&s| s > 0.0), "empty predicate generated");
    }

    #[test]
    fn match_all_option() {
        let ds = generate(by_name("test").unwrap(), 500, 5);
        let opts = WorkloadOptions { selectivity: 1.0, n_queries: 5, ..Default::default() };
        let w = generate_workload(&ds, &opts, 6);
        assert!(w.queries.iter().all(|q| q.predicate.is_match_all()));
    }

    #[test]
    fn arrival_model() {
        let m = ArrivalModel::Uniform { volume: 86_400, period_s: 86_400.0 };
        assert!((m.mean_gap_s() - 1.0).abs() < 1e-9);
        assert_eq!(m.volume(), 86_400);
    }

    #[test]
    fn deterministic() {
        let ds = generate(by_name("test").unwrap(), 1000, 7);
        let a = generate_workload(&ds, &WorkloadOptions::default(), 8);
        let b = generate_workload(&ds, &WorkloadOptions::default(), 8);
        assert_eq!(a.queries.len(), b.queries.len());
        assert_eq!(a.queries[0].vector, b.queries[0].vector);
        assert_eq!(a.queries[0].predicate, b.queries[0].predicate);
    }
}
