//! Attribute generation (paper §5.1): "we generate A = 4 uniform
//! attributes for each dataset", supporting both real-valued and
//! categorical kinds. Numeric attributes are grid-valued (integers
//! 0..=99) so quantized filtering is exact — see `attrs::quantize`.

use crate::attrs::quantize::AttrValue;
use crate::util::rng::Rng;

/// Grid size for numeric attributes (100 distinct values, like price
/// points or star ratings scaled).
pub const NUMERIC_GRID: usize = 100;

/// Cardinality for the categorical attribute when A >= 4.
pub const CATEGORICAL_CARD: usize = 16;

/// Generate per-vector attribute rows: attributes 0..A-2 are uniform
/// numeric on the grid; the last is categorical (mixed-type coverage —
/// the paper supports both kinds).
pub fn generate_attributes(n: usize, a: usize, rng: &mut Rng) -> Vec<Vec<AttrValue>> {
    (0..n)
        .map(|_| {
            (0..a)
                .map(|attr| {
                    if attr + 1 == a && a > 1 {
                        AttrValue::Cat(rng.gen_range(CATEGORICAL_CARD) as u32)
                    } else {
                        AttrValue::Num(rng.gen_range(NUMERIC_GRID) as f32)
                    }
                })
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_kinds() {
        let mut rng = Rng::new(1);
        let rows = generate_attributes(200, 4, &mut rng);
        assert_eq!(rows.len(), 200);
        for r in &rows {
            assert_eq!(r.len(), 4);
            for v in &r[..3] {
                match v {
                    AttrValue::Num(x) => {
                        assert!(*x >= 0.0 && *x < NUMERIC_GRID as f32 && x.fract() == 0.0)
                    }
                    _ => panic!("expected numeric"),
                }
            }
            match r[3] {
                AttrValue::Cat(c) => assert!((c as usize) < CATEGORICAL_CARD),
                _ => panic!("expected categorical"),
            }
        }
    }

    #[test]
    fn roughly_uniform() {
        let mut rng = Rng::new(2);
        let rows = generate_attributes(20_000, 2, &mut rng);
        let mut hist = vec![0usize; NUMERIC_GRID];
        for r in &rows {
            hist[r[0].as_f32() as usize] += 1;
        }
        let expect = 20_000 / NUMERIC_GRID;
        for (v, &c) in hist.iter().enumerate() {
            assert!(
                c > expect / 2 && c < expect * 2,
                "value {v} count {c} vs expect {expect}"
            );
        }
    }
}
