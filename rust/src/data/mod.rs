//! Datasets, attributes, workloads and ground truth (paper §5.1).
//!
//! The paper evaluates on SIFT1M / GIST1M / SIFT10M / DEEP10M. Those
//! binaries are not available offline, so `synthetic` generates clustered
//! datasets with the same dimensionality and a matched difficulty knob
//! (cluster count / noise / anisotropy standing in for LID) — see
//! DESIGN.md §2 for the substitution argument. All sizes are config
//! driven; the defaults keep CI fast while `--scale` reproduces larger
//! runs.

pub mod attributes;
pub mod ground_truth;
pub mod profiles;
pub mod synthetic;
pub mod workload;

use crate::attrs::quantize::AttrValue;
use crate::util::matrix::Matrix;

/// An attributed vector dataset.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub vectors: Matrix,
    /// per-vector attribute rows (A values each)
    pub attributes: Vec<Vec<AttrValue>>,
}

impl Dataset {
    pub fn n(&self) -> usize {
        self.vectors.n()
    }

    pub fn d(&self) -> usize {
        self.vectors.d()
    }

    pub fn n_attrs(&self) -> usize {
        self.attributes.first().map(|a| a.len()).unwrap_or(0)
    }

    /// Size of the raw full-precision vectors on disk (EFS cost input).
    pub fn vector_bytes(&self) -> usize {
        self.n() * self.d() * 4
    }
}
