//! Synthetic attributed-vector dataset generation.
//!
//! Clustered anisotropic Gaussian mixtures: cluster centers are spread in
//! a low-ish effective-rank subspace (energy decays per dimension, like
//! real descriptor data after whitening), with per-cluster noise. This
//! reproduces the properties OSQ exploits — correlated dimensions with
//! decaying variance (KLT + non-uniform bit allocation), and cluster
//! structure (balanced partitioning + threshold-based selection).

use crate::data::attributes::generate_attributes;
use crate::data::profiles::Profile;
use crate::data::Dataset;
use crate::util::matrix::Matrix;
use crate::util::rng::Rng;

/// Generate a dataset for a profile at size `n` (0 = profile default).
pub fn generate(profile: &Profile, n: usize, seed: u64) -> Dataset {
    let n = if n == 0 { profile.default_n } else { n };
    let d = profile.d;
    let k = profile.clusters;
    let mut rng = Rng::new(seed ^ 0x5941_7444);

    // per-dimension energy decay: var_j ~ 1 / (1 + j)^0.7, randomly
    // permuted so the interesting dims are not axis-aligned-by-index
    let mut scales: Vec<f32> =
        (0..d).map(|j| (1.0 / (1.0 + j as f32).powf(0.7)).sqrt()).collect();
    rng.shuffle(&mut scales);

    // cluster centers + per-cluster anisotropy
    let centers: Vec<Vec<f32>> = (0..k)
        .map(|_| (0..d).map(|j| rng.normal() * 3.0 * scales[j]).collect())
        .collect();
    let cluster_noise: Vec<f32> =
        (0..k).map(|_| profile.noise * rng.f32_range(0.6, 1.4)).collect();

    let mut crng = rng.fork(1);
    let vectors = Matrix::from_rows_fn(n, d, |_, row| {
        let c = crng.gen_range(k);
        for (j, v) in row.iter_mut().enumerate() {
            *v = centers[c][j] + crng.normal() * cluster_noise[c] * scales[j];
        }
    });

    let attributes = generate_attributes(n, profile.n_attrs, &mut rng.fork(2));
    Dataset { name: profile.name.to_string(), vectors, attributes }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::profiles::by_name;

    #[test]
    fn shapes_match_profile() {
        let p = by_name("test").unwrap();
        let ds = generate(p, 500, 7);
        assert_eq!(ds.n(), 500);
        assert_eq!(ds.d(), 16);
        assert_eq!(ds.n_attrs(), 4);
        assert_eq!(ds.attributes.len(), 500);
    }

    #[test]
    fn deterministic() {
        let p = by_name("test").unwrap();
        let a = generate(p, 100, 42);
        let b = generate(p, 100, 42);
        assert_eq!(a.vectors, b.vectors);
        assert_eq!(a.attributes, b.attributes);
    }

    #[test]
    fn different_seeds_differ() {
        let p = by_name("test").unwrap();
        let a = generate(p, 100, 1);
        let b = generate(p, 100, 2);
        assert_ne!(a.vectors, b.vectors);
    }

    #[test]
    fn variance_is_nonuniform() {
        // the energy-decay knob must produce dims worth > 4 bits and dims
        // worth < 4 bits, or the non-uniform allocation is pointless
        let p = by_name("test").unwrap();
        let ds = generate(p, 2000, 3);
        let vars = ds.vectors.col_variances();
        let max = vars.iter().cloned().fold(0f32, f32::max);
        let min = vars.iter().cloned().fold(f32::INFINITY, f32::min);
        assert!(max / min.max(1e-9) > 4.0, "variance ratio {}", max / min);
    }

    #[test]
    fn clustered_not_degenerate() {
        let p = by_name("test").unwrap();
        let ds = generate(p, 1000, 9);
        // nearest-neighbor distance should be much smaller than the
        // average pairwise distance in a clustered set
        let m = &ds.vectors;
        let mut rng = crate::util::rng::Rng::new(11);
        let mut nn_sum = 0f64;
        let mut avg_sum = 0f64;
        for _ in 0..30 {
            let i = rng.gen_range(m.n());
            let mut nn = f32::INFINITY;
            let mut avg = 0f64;
            for j in 0..m.n() {
                if i == j {
                    continue;
                }
                let d2 = crate::util::matrix::l2_sq(m.row(i), m.row(j));
                nn = nn.min(d2);
                avg += d2 as f64;
            }
            nn_sum += nn as f64;
            avg_sum += avg / (m.n() - 1) as f64;
        }
        assert!(nn_sum * 4.0 < avg_sum, "no cluster structure: {nn_sum} vs {avg_sum}");
    }
}
