//! Request-lifecycle resilience primitives: deadlines, retry budgets,
//! and per-function-pool circuit breakers — all on the deterministic
//! virtual clock ([`crate::storage::virtual_now`]), so every recovery
//! decision replays byte-identically under a fixed chaos seed.
//!
//! # Deadline debiting
//!
//! A [`Deadline`] is an *absolute* point on the virtual timeline. The
//! client stamps one at batch entry (`SquashConfig::deadline_s`); it
//! rides in every CO→QA→QP request payload and is re-read at each hop,
//! so an invocation's timeout is always `deadline.remaining()` — the
//! budget left *after* everything upstream (queueing, retries, backoff,
//! sibling stragglers) has already been debited from the shared clock.
//! `Deadline::none()` (+∞, the default) makes every check a no-op, so
//! deadline-free runs stay bit-identical to the pre-resilience code.
//!
//! # Retry budgets with backoff
//!
//! [`RetryPolicy`] bounds `invoke_with_policy`'s loop: at most
//! `max_attempts` tries per request, with capped exponential backoff
//! between them. Backoff jitter is drawn from the same SplitMix
//! construction as the chaos model — keyed by `(chaos seed, function,
//! attempt)` — never from a wall clock, so a retry storm replays
//! exactly. [`RetryPolicy::legacy`] (the default) reproduces the
//! pre-resilience behavior: 32 immediate attempts, no backoff.
//!
//! # Breaker state machine
//!
//! One [`CircuitBreaker`] per function pool, evaluated on virtual time:
//!
//! ```text
//!          failure rate ≥ threshold over the rolling window
//!   Closed ───────────────────────────────────────────────▶ Open
//!     ▲                                                      │
//!     │ probe succeeds                        now ≥ open_until│
//!     │                                                      ▼
//!     └────────────────────────────────────────────────── HalfOpen
//!                      probe fails → back to Open
//! ```
//!
//! While Open, `admit` returns false and the caller fails fast with
//! [`super::FaasError::CircuitOpen`] — no container is acquired, nothing
//! is billed, no doomed work queues behind a sick pool. After `open_s`
//! virtual seconds one probe invocation is admitted (HalfOpen); its
//! outcome closes or re-opens the breaker. Disabled (the default) the
//! breaker admits everything and records nothing.

use crate::util::rng::{mix64, Rng};

/// An absolute virtual-time deadline carried through the request tree.
/// `INFINITY` means "no deadline" and makes every operation a no-op.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Deadline {
    /// absolute virtual time (seconds) at which the request expires
    pub at: f64,
}

impl Default for Deadline {
    fn default() -> Self {
        Self::none()
    }
}

impl Deadline {
    /// No deadline: every budget check passes, `remaining` is +∞.
    pub fn none() -> Self {
        Self { at: f64::INFINITY }
    }

    /// Deadline at absolute virtual time `t`.
    pub fn at(t: f64) -> Self {
        Self { at: t }
    }

    /// Deadline `budget_s` virtual seconds after `now`.
    pub fn in_s(now: f64, budget_s: f64) -> Self {
        Self { at: now + budget_s }
    }

    pub fn is_none(&self) -> bool {
        self.at.is_infinite()
    }

    /// Budget left at virtual time `now` (may be ≤ 0; +∞ when unset).
    pub fn remaining(&self, now: f64) -> f64 {
        self.at - now
    }

    pub fn expired(&self, now: f64) -> bool {
        now >= self.at
    }

    /// Wire encoding: the raw bits of the absolute time (`INFINITY`
    /// round-trips exactly, so "no deadline" survives the hop).
    pub fn to_bits(&self) -> u64 {
        self.at.to_bits()
    }

    pub fn from_bits(bits: u64) -> Self {
        Self { at: f64::from_bits(bits) }
    }
}

/// Bounded-retry policy with capped exponential backoff and seeded
/// deterministic jitter.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RetryPolicy {
    /// total attempts per request (first try included); ≥ 1
    pub max_attempts: usize,
    /// backoff before retry k (1-based): `base · multiplier^(k-1)`,
    /// capped at `max_backoff_s`. 0 = immediate retry.
    pub base_backoff_s: f64,
    pub backoff_multiplier: f64,
    pub max_backoff_s: f64,
    /// jitter fraction in [0, 1]: the drawn wait is
    /// `backoff · (1 - jitter·u)` with `u` uniform in [0, 1) — "full
    /// jitter below", never exceeding the deterministic envelope
    pub jitter: f64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self::legacy()
    }
}

impl RetryPolicy {
    /// The pre-resilience behavior, bit-identical: 32 immediate
    /// attempts, no backoff, no jitter.
    pub fn legacy() -> Self {
        Self {
            max_attempts: 32,
            base_backoff_s: 0.0,
            backoff_multiplier: 2.0,
            max_backoff_s: 0.0,
            jitter: 0.0,
        }
    }

    /// A production-shaped budget: 4 attempts, 25 ms base doubling to a
    /// 400 ms cap, 50% jitter.
    pub fn standard() -> Self {
        Self {
            max_attempts: 4,
            base_backoff_s: 0.025,
            backoff_multiplier: 2.0,
            max_backoff_s: 0.4,
            jitter: 0.5,
        }
    }

    /// Deterministic backoff before retry `attempt` (1-based). The
    /// jitter draw is a pure function of `(jitter_key, attempt)`.
    pub fn backoff_s(&self, attempt: usize, jitter_key: u64) -> f64 {
        if self.base_backoff_s <= 0.0 || attempt == 0 {
            return 0.0;
        }
        let exp = self.base_backoff_s * self.backoff_multiplier.powi(attempt as i32 - 1);
        let capped = exp.min(self.max_backoff_s.max(self.base_backoff_s));
        if self.jitter <= 0.0 {
            return capped;
        }
        let mut rng = Rng::new(mix64(jitter_key) ^ mix64(0xBACC_0FF ^ attempt as u64));
        capped * (1.0 - self.jitter * rng.f64())
    }
}

/// Circuit-breaker configuration. Disabled by default: `admit` always
/// passes and no state is kept, so the breaker is inert unless opted in.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BreakerConfig {
    pub enabled: bool,
    /// rolling outcome window size (most recent N attempts)
    pub window: usize,
    /// minimum samples in the window before the breaker may open
    pub min_samples: usize,
    /// failure fraction over the window at/above which it opens
    pub failure_threshold: f64,
    /// virtual seconds to stay Open before admitting a half-open probe
    pub open_s: f64,
}

impl Default for BreakerConfig {
    fn default() -> Self {
        Self::off()
    }
}

impl BreakerConfig {
    pub fn off() -> Self {
        Self {
            enabled: false,
            window: 16,
            min_samples: 8,
            failure_threshold: 0.5,
            open_s: 1.0,
        }
    }

    /// Enabled with the stock shape (16-sample window, ≥ 8 samples, 50%
    /// failure rate opens for 1 virtual second).
    pub fn on() -> Self {
        Self { enabled: true, ..Self::off() }
    }
}

#[derive(Clone, Copy, Debug, PartialEq)]
enum BreakerState {
    Closed,
    Open { until: f64 },
    /// one probe is in flight; its outcome decides Closed vs Open
    HalfOpen,
}

/// Per-function-pool circuit breaker on virtual time (see module docs).
#[derive(Debug)]
pub struct CircuitBreaker {
    cfg: BreakerConfig,
    state: BreakerState,
    /// rolling window of recent outcomes (true = failure)
    window: std::collections::VecDeque<bool>,
    /// times the breaker transitioned Closed/HalfOpen → Open
    pub opens: u64,
}

impl CircuitBreaker {
    pub fn new(cfg: BreakerConfig) -> Self {
        Self {
            cfg,
            state: BreakerState::Closed,
            window: std::collections::VecDeque::with_capacity(cfg.window),
            opens: 0,
        }
    }

    /// May a request proceed at virtual time `now`? Open breakers reject
    /// until `open_s` has elapsed, then admit exactly one probe.
    pub fn admit(&mut self, now: f64) -> bool {
        if !self.cfg.enabled {
            return true;
        }
        match self.state {
            BreakerState::Closed | BreakerState::HalfOpen => true,
            BreakerState::Open { until } => {
                if now >= until {
                    self.state = BreakerState::HalfOpen;
                    true
                } else {
                    false
                }
            }
        }
    }

    /// Record an attempt outcome at virtual time `now`.
    pub fn record(&mut self, now: f64, failed: bool) {
        if !self.cfg.enabled {
            return;
        }
        match self.state {
            BreakerState::HalfOpen => {
                if failed {
                    self.trip(now);
                } else {
                    self.state = BreakerState::Closed;
                    self.window.clear();
                }
            }
            BreakerState::Closed => {
                if self.window.len() == self.cfg.window.max(1) {
                    self.window.pop_front();
                }
                self.window.push_back(failed);
                let n = self.window.len();
                if n >= self.cfg.min_samples.max(1) {
                    let failures = self.window.iter().filter(|&&f| f).count();
                    if failures as f64 / n as f64 >= self.cfg.failure_threshold {
                        self.trip(now);
                    }
                }
            }
            // outcomes of requests admitted before the trip land here;
            // the breaker is already open, nothing more to learn
            BreakerState::Open { .. } => {}
        }
    }

    fn trip(&mut self, now: f64) {
        self.state = BreakerState::Open { until: now + self.cfg.open_s };
        self.window.clear();
        self.opens += 1;
    }

    pub fn is_open(&self) -> bool {
        matches!(self.state, BreakerState::Open { .. })
    }

    /// Would [`CircuitBreaker::admit`] at `now` transition this Open
    /// breaker to its half-open probe? A pure peek — no state changes —
    /// so callers can decide *how* to spend the probe (e.g. ride it on a
    /// hedge duplicate) before admitting anything. False while Closed or
    /// HalfOpen: no probe is pending there.
    pub fn probe_ready(&self, now: f64) -> bool {
        matches!(self.state, BreakerState::Open { until } if now >= until)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deadline_none_never_expires_and_roundtrips() {
        let d = Deadline::none();
        assert!(d.is_none());
        assert!(!d.expired(1e18));
        assert!(d.remaining(1e18).is_infinite());
        let rt = Deadline::from_bits(d.to_bits());
        assert!(rt.is_none());
        let d = Deadline::in_s(2.0, 0.5);
        assert_eq!(d.at, 2.5);
        assert!((d.remaining(2.1) - 0.4).abs() < 1e-12);
        assert!(!d.expired(2.4));
        assert!(d.expired(2.5));
        assert_eq!(Deadline::from_bits(d.to_bits()), d);
    }

    #[test]
    fn legacy_policy_is_the_old_loop() {
        let p = RetryPolicy::legacy();
        assert_eq!(p.max_attempts, 32);
        for attempt in 0..40 {
            assert_eq!(p.backoff_s(attempt, 123), 0.0, "legacy never waits");
        }
        assert_eq!(RetryPolicy::default(), p);
    }

    #[test]
    fn backoff_grows_caps_and_jitters_deterministically() {
        let p = RetryPolicy { jitter: 0.0, ..RetryPolicy::standard() };
        assert_eq!(p.backoff_s(1, 0), 0.025);
        assert_eq!(p.backoff_s(2, 0), 0.05);
        assert_eq!(p.backoff_s(3, 0), 0.1);
        assert_eq!(p.backoff_s(6, 0), 0.4, "capped at max_backoff_s");
        let j = RetryPolicy::standard();
        for attempt in 1..8 {
            let a = j.backoff_s(attempt, 42);
            let b = j.backoff_s(attempt, 42);
            assert_eq!(a.to_bits(), b.to_bits(), "jitter must replay");
            let envelope = p.backoff_s(attempt, 0);
            assert!(a <= envelope && a >= envelope * 0.5, "full-jitter-below bounds: {a}");
        }
        assert_ne!(
            j.backoff_s(1, 1).to_bits(),
            j.backoff_s(1, 2).to_bits(),
            "distinct keys draw distinct jitter"
        );
    }

    #[test]
    fn breaker_disabled_is_inert() {
        let mut b = CircuitBreaker::new(BreakerConfig::off());
        for _ in 0..100 {
            assert!(b.admit(0.0));
            b.record(0.0, true);
        }
        assert!(!b.is_open());
        assert_eq!(b.opens, 0);
    }

    #[test]
    fn breaker_opens_probes_and_recloses() {
        let cfg = BreakerConfig {
            enabled: true,
            window: 4,
            min_samples: 4,
            failure_threshold: 0.5,
            open_s: 1.0,
        };
        let mut b = CircuitBreaker::new(cfg);
        // below min_samples nothing trips
        b.record(0.0, true);
        b.record(0.0, true);
        assert!(b.admit(0.0));
        // two more failures: 4/4 ≥ 0.5 → Open until t=1
        b.record(0.0, true);
        b.record(0.0, true);
        assert!(b.is_open());
        assert_eq!(b.opens, 1);
        assert!(!b.admit(0.5), "open breaker fails fast");
        // after open_s: one probe admitted (HalfOpen)
        assert!(b.admit(1.5));
        // probe fails → re-open
        b.record(1.5, true);
        assert!(b.is_open());
        assert_eq!(b.opens, 2);
        // next probe succeeds → Closed with a cleared window
        assert!(b.admit(3.0));
        b.record(3.0, false);
        assert!(!b.is_open());
        // a single new failure can't instantly re-trip (window cleared)
        b.record(3.0, true);
        assert!(!b.is_open());
    }

    #[test]
    fn probe_ready_peeks_without_transitioning() {
        let cfg = BreakerConfig {
            enabled: true,
            window: 2,
            min_samples: 2,
            failure_threshold: 0.5,
            open_s: 1.0,
        };
        let mut b = CircuitBreaker::new(cfg);
        assert!(!b.probe_ready(0.0), "closed breaker has no pending probe");
        b.record(0.0, true);
        b.record(0.0, true);
        assert!(b.is_open());
        assert!(!b.probe_ready(0.5), "still inside the open window");
        assert!(b.probe_ready(1.5), "open window elapsed: a probe is due");
        // the peek must not consume the probe: admit still transitions
        assert!(b.is_open(), "probe_ready left the state untouched");
        assert!(b.admit(1.5));
        assert!(!b.probe_ready(1.5), "half-open: the probe is in flight");
    }
}
