//! FaaS platform simulator (paper §3): Lambda-like function containers
//! with cold/warm starts, synchronous invocation with request/response
//! payloads, per-invocation billing, and container reuse — the substrate
//! for Data Retention Exploitation (§3.2).
//!
//! What is simulated vs real: *compute inside a handler runs for real on
//! this host*; invocation overheads, payload transfer and storage I/O are
//! modeled latencies injected through [`SimParams`] (scaled sleeps).
//! Billing follows AWS semantics: a function is billed for its wall
//! duration — including time blocked on child invocations — at its
//! configured memory. When `time_scale == 0` (unit tests) the modeled
//! latencies are still *billed* via a thread-local accumulator even
//! though nothing sleeps.

pub mod dre;

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::cost::{CostLedger, Role};
use crate::storage::{take_modeled_extra, SimParams};
use dre::DreStore;

/// Platform configuration (paper §5.3 defaults).
#[derive(Clone, Debug)]
pub struct FaasConfig {
    pub memory_co_mb: u32,
    pub memory_qa_mb: u32,
    pub memory_qp_mb: u32,
    /// cold start: sandbox creation + INIT phase
    pub cold_start_s: f64,
    /// warm invocation dispatch overhead
    pub warm_start_s: f64,
    /// request/response payload bandwidth
    pub payload_bandwidth_bps: f64,
    /// AWS synchronous invocation payload cap (6 MB)
    pub max_payload_bytes: usize,
    /// Data Retention Exploitation on/off (Fig 6 ablation)
    pub dre_enabled: bool,
}

impl Default for FaasConfig {
    fn default() -> Self {
        Self {
            memory_co_mb: 512,
            memory_qa_mb: 1770,
            memory_qp_mb: 1770,
            cold_start_s: 0.18,
            warm_start_s: 0.006,
            payload_bandwidth_bps: 40e6,
            max_payload_bytes: 6 * 1024 * 1024,
            dre_enabled: true,
        }
    }
}

/// A runtime container (execution environment). Its `retained` store
/// survives across invocations of the same function — the mechanism DRE
/// exploits via singleton objects.
pub struct Container {
    pub id: u64,
    pub invocations: u64,
    pub retained: DreStore,
}

/// Handler context: what a function sees during one invocation.
pub struct InvocationCtx<'a> {
    pub container: &'a mut Container,
    pub dre_enabled: bool,
    pub function: &'a str,
}

impl InvocationCtx<'_> {
    /// DRE read: present only on warm containers with DRE enabled.
    pub fn dre_get<T: Send + Sync + 'static>(&self, key: &str) -> Option<Arc<T>> {
        if !self.dre_enabled {
            return None;
        }
        self.container.retained.get(key)
    }

    /// DRE write (no-op when disabled, mirroring handlers that skip the
    /// singleton when the feature flag is off).
    pub fn dre_put<T: Send + Sync + 'static>(&mut self, key: &str, value: Arc<T>) {
        if self.dre_enabled {
            self.container.retained.put(key, value);
        }
    }
}

#[derive(Debug)]
pub enum FaasError {
    PayloadTooLarge(usize, usize),
}

impl std::fmt::Display for FaasError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FaasError::PayloadTooLarge(got, cap) => {
                write!(f, "payload of {got} bytes exceeds the synchronous invocation cap {cap}")
            }
        }
    }
}

impl std::error::Error for FaasError {}

/// The Lambda-like platform: per-function container pools.
pub struct Platform {
    pools: Mutex<HashMap<String, Vec<Container>>>,
    next_container: AtomicU64,
    pub config: FaasConfig,
    pub params: SimParams,
    pub ledger: Arc<CostLedger>,
    pub warm_invocations: AtomicU64,
    pub cold_invocations: AtomicU64,
}

impl Platform {
    pub fn new(config: FaasConfig, params: SimParams, ledger: Arc<CostLedger>) -> Self {
        Self {
            pools: Mutex::new(HashMap::new()),
            next_container: AtomicU64::new(0),
            config,
            params,
            ledger,
            warm_invocations: AtomicU64::new(0),
            cold_invocations: AtomicU64::new(0),
        }
    }

    fn memory_for(&self, role: Role) -> u32 {
        match role {
            Role::Coordinator => self.config.memory_co_mb,
            Role::QueryAllocator => self.config.memory_qa_mb,
            // QP shard functions are deployed at the QP memory size: each
            // one runs the same scan kernels over a row sub-range
            Role::QueryProcessor | Role::QpShard => self.config.memory_qp_mb,
        }
    }

    /// Synchronously invoke `function`: acquire a container (warm if one
    /// is idle, else cold), transfer the request payload, run `handler`,
    /// transfer the response, release the container, bill everything.
    pub fn invoke<F>(
        &self,
        function: &str,
        role: Role,
        payload: &[u8],
        handler: F,
    ) -> Result<Vec<u8>, FaasError>
    where
        F: FnOnce(&mut InvocationCtx, &[u8]) -> Vec<u8>,
    {
        if payload.len() > self.config.max_payload_bytes {
            return Err(FaasError::PayloadTooLarge(payload.len(), self.config.max_payload_bytes));
        }
        // acquire container
        let (mut container, cold) = {
            let mut pools = self.pools.lock().unwrap();
            match pools.get_mut(function).and_then(|v| v.pop()) {
                Some(c) => (c, false),
                None => (
                    Container {
                        id: self.next_container.fetch_add(1, Ordering::Relaxed),
                        invocations: 0,
                        retained: DreStore::new(),
                    },
                    true,
                ),
            }
        };
        self.ledger.record_invocation(role, cold);
        if cold {
            self.cold_invocations.fetch_add(1, Ordering::Relaxed);
        } else {
            self.warm_invocations.fetch_add(1, Ordering::Relaxed);
        }

        let start = std::time::Instant::now();
        take_modeled_extra(); // reset the billing accumulator

        // startup + request payload transfer
        let startup = if cold { self.config.cold_start_s } else { self.config.warm_start_s };
        let transfer_in = payload.len() as f64 / self.config.payload_bandwidth_bps;
        self.params.simulate_latency(startup + transfer_in);
        self.ledger.record_payload(payload.len() as u64);

        // INVOKE phase: run the handler
        container.invocations += 1;
        let mut ctx = InvocationCtx {
            container: &mut container,
            dre_enabled: self.config.dre_enabled,
            function,
        };
        let response = handler(&mut ctx, payload);
        // AWS enforces the same cap on synchronous *responses*; the
        // failed invocation's container is dropped, not repooled.
        if response.len() > self.config.max_payload_bytes {
            return Err(FaasError::PayloadTooLarge(
                response.len(),
                self.config.max_payload_bytes,
            ));
        }

        // response payload transfer
        let transfer_out = response.len() as f64 / self.config.payload_bandwidth_bps;
        self.params.simulate_latency(transfer_out);
        self.ledger.record_payload(response.len() as u64);

        // billing: wall duration + modeled-but-unslept latencies
        let extra = take_modeled_extra();
        let billed = start.elapsed().as_secs_f64() + extra;
        self.ledger.record_runtime(role, self.memory_for(role), billed);

        // release container to the pool (warm for the next invocation)
        self.pools.lock().unwrap().entry(function.to_string()).or_default().push(container);
        Ok(response)
    }

    /// Number of idle containers for a function (tests/diagnostics).
    pub fn pool_size(&self, function: &str) -> usize {
        self.pools.lock().unwrap().get(function).map(|v| v.len()).unwrap_or(0)
    }

    /// Distinct function pools whose name starts with `prefix`
    /// (tests/diagnostics: e.g. counting the per-shard QP fleets of one
    /// partition — each shard function owns its own containers and DRE
    /// store, so the multi-function scatter must create one pool per
    /// shard, never share one).
    pub fn pools_with_prefix(&self, prefix: &str) -> usize {
        self.pools
            .lock()
            .unwrap()
            .iter()
            .filter(|(name, pool)| name.starts_with(prefix) && !pool.is_empty())
            .count()
    }

    /// Drop all containers — simulates a cold fleet / redeployment.
    pub fn reset_containers(&self) {
        self.pools.lock().unwrap().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn platform(dre: bool) -> Platform {
        let ledger = Arc::new(CostLedger::new());
        Platform::new(
            FaasConfig { dre_enabled: dre, ..Default::default() },
            SimParams::instant(),
            ledger,
        )
    }

    #[test]
    fn cold_then_warm() {
        let p = platform(true);
        for i in 0..3 {
            let r = p
                .invoke("f", Role::QueryProcessor, b"ping", |ctx, payload| {
                    assert_eq!(payload, b"ping");
                    assert_eq!(ctx.function, "f");
                    vec![i]
                })
                .unwrap();
            assert_eq!(r, vec![i]);
        }
        assert_eq!(p.cold_invocations.load(Ordering::Relaxed), 1);
        assert_eq!(p.warm_invocations.load(Ordering::Relaxed), 2);
        assert_eq!(p.pool_size("f"), 1);
    }

    #[test]
    fn concurrent_invocations_get_distinct_containers() {
        let p = Arc::new(platform(true));
        let barrier = Arc::new(std::sync::Barrier::new(4));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let p = p.clone();
            let b = barrier.clone();
            handles.push(std::thread::spawn(move || {
                p.invoke("g", Role::QueryAllocator, b"", |ctx, _| {
                    b.wait(); // hold all 4 containers simultaneously
                    vec![ctx.container.id as u8]
                })
                .unwrap()[0]
            }));
        }
        let mut ids: Vec<u8> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 4, "containers must not be shared concurrently");
        assert_eq!(p.cold_invocations.load(Ordering::Relaxed), 4);
        assert_eq!(p.pool_size("g"), 4);
    }

    #[test]
    fn dre_retains_across_invocations() {
        let p = platform(true);
        p.invoke("h", Role::QueryProcessor, b"", |ctx, _| {
            assert!(ctx.dre_get::<Vec<u8>>("index").is_none());
            ctx.dre_put("index", Arc::new(vec![9u8, 9, 9]));
            vec![]
        })
        .unwrap();
        p.invoke("h", Role::QueryProcessor, b"", |ctx, _| {
            let got = ctx.dre_get::<Vec<u8>>("index").expect("retained data");
            assert_eq!(*got, vec![9u8, 9, 9]);
            vec![]
        })
        .unwrap();
    }

    #[test]
    fn dre_disabled_sees_nothing() {
        let p = platform(false);
        p.invoke("h", Role::QueryProcessor, b"", |ctx, _| {
            ctx.dre_put("index", Arc::new(1u32)); // no-op
            vec![]
        })
        .unwrap();
        p.invoke("h", Role::QueryProcessor, b"", |ctx, _| {
            assert!(ctx.dre_get::<u32>("index").is_none());
            vec![]
        })
        .unwrap();
    }

    #[test]
    fn per_function_pools_are_separate() {
        // the paper names a function per partition (squash-processor-0,
        // squash-processor-1, ...) so retained indexes can't cross
        let p = platform(true);
        p.invoke("squash-processor-0", Role::QueryProcessor, b"", |ctx, _| {
            ctx.dre_put("index", Arc::new(0usize));
            vec![]
        })
        .unwrap();
        p.invoke("squash-processor-1", Role::QueryProcessor, b"", |ctx, _| {
            assert!(ctx.dre_get::<usize>("index").is_none());
            vec![]
        })
        .unwrap();
        assert_eq!(p.pool_size("squash-processor-0"), 1);
        assert_eq!(p.pool_size("squash-processor-1"), 1);
    }

    #[test]
    fn shard_functions_get_distinct_pools_and_dre_stores() {
        // the multi-function QP scatter names one function per row-range
        // shard; each must cold-start its own container and retain its
        // own copy of the partition index
        let p = platform(true);
        for s in 0..3usize {
            let f = format!("squash-processor-4-shard-{s}of3");
            p.invoke(&f, Role::QpShard, b"", |ctx, _| {
                assert!(ctx.dre_get::<usize>("partition-4").is_none());
                ctx.dre_put("partition-4", Arc::new(s));
                vec![]
            })
            .unwrap();
        }
        assert_eq!(p.pools_with_prefix("squash-processor-4-shard-"), 3);
        assert_eq!(p.pools_with_prefix("squash-processor-4"), 3);
        assert_eq!(p.pools_with_prefix("squash-processor-9"), 0);
        assert_eq!(p.cold_invocations.load(Ordering::Relaxed), 3);
        // warm reuse stays within the shard's own pool
        p.invoke("squash-processor-4-shard-1of3", Role::QpShard, b"", |ctx, _| {
            assert_eq!(*ctx.dre_get::<usize>("partition-4").unwrap(), 1);
            vec![]
        })
        .unwrap();
        assert_eq!(p.warm_invocations.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn payload_cap_enforced() {
        let p = platform(true);
        let big = vec![0u8; p.config.max_payload_bytes + 1];
        let r = p.invoke("f", Role::Coordinator, &big, |_, _| vec![]);
        assert!(matches!(r, Err(FaasError::PayloadTooLarge(_, _))));
    }

    #[test]
    fn response_cap_enforced_too() {
        let p = platform(true);
        let n = p.config.max_payload_bytes + 1;
        let r = p.invoke("f", Role::QueryProcessor, b"", move |_, _| vec![0u8; n]);
        assert!(matches!(r, Err(FaasError::PayloadTooLarge(_, _))));
        // an in-cap response still round-trips
        let ok = p.invoke("f", Role::QueryProcessor, b"", |_, _| vec![1u8]).unwrap();
        assert_eq!(ok, vec![1u8]);
    }

    #[test]
    fn billing_includes_modeled_latency_at_scale_zero() {
        let p = platform(true);
        p.invoke("f", Role::QueryProcessor, b"x", |_, _| vec![0u8; 1000]).unwrap();
        // billed runtime must include the (unslept) cold start
        let mbs = p.ledger.mb_seconds(Role::QueryProcessor);
        let billed_s = mbs / p.config.memory_qp_mb as f64;
        assert!(billed_s >= p.config.cold_start_s, "billed {billed_s}");
    }

    #[test]
    fn reset_makes_everything_cold_again() {
        let p = platform(true);
        p.invoke("f", Role::QueryProcessor, b"", |_, _| vec![]).unwrap();
        p.reset_containers();
        p.invoke("f", Role::QueryProcessor, b"", |_, _| vec![]).unwrap();
        assert_eq!(p.cold_invocations.load(Ordering::Relaxed), 2);
    }
}
