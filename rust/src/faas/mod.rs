//! FaaS platform simulator (paper §3): Lambda-like function containers
//! with cold/warm starts, synchronous invocation with request/response
//! payloads, per-invocation billing, and container reuse — the substrate
//! for Data Retention Exploitation (§3.2).
//!
//! What is simulated vs real: *compute inside a handler runs for real on
//! this host*; invocation overheads, payload transfer and storage I/O are
//! modeled latencies injected through [`SimParams`] (scaled sleeps).
//! Billing follows AWS semantics: a function is billed for its wall
//! duration — including time blocked on child invocations — at its
//! configured memory. When `time_scale == 0` (unit tests) the modeled
//! latencies are still *billed* via a thread-local accumulator even
//! though nothing sleeps.
//!
//! # Tail-latency / fault injection ([`ChaosConfig`], [`LatencyModel`])
//!
//! Real FaaS latency is governed by the tail: sandbox-placement stalls,
//! cold-start outliers, the occasional failed invocation. The seed
//! simulator modeled all of that with zero variance, so tail-tolerance
//! machinery (straggler hedging, shard auto-tuning) had nothing to push
//! against. [`LatencyModel`] is the seeded seam: every invocation draws a
//! lognormal-style overhead multiplier, an occasional cold-start-class
//! spike, and an injectable failure from a hash of
//! `(chaos seed, function name, per-function invocation counter)` —
//! fully deterministic, no `Instant`-dependent behavior. Jitter is
//! *pure-tail* (the multiplier is clamped at ≥ 1), so chaos only ever
//! adds modeled latency; every billing lower bound that holds at zero
//! variance still holds under chaos.
//!
//! Each invocation's **modeled duration** (startup + payload transfers +
//! handler storage I/O + jitter, excluding real compute time) is
//! returned via [`Invocation::modeled_s`]; the coordinator's hedged
//! scatter joins shards on these virtual completion times. Injected
//! failures are billed (AWS bills failed synchronous invocations), the
//! failing container is dropped — never repooled — and
//! [`Platform::invoke_retrying`] retries with fresh draws, so a retry
//! can never land on the container that just failed.
//!
//! # Event-driven fleet mode (`FaasConfig::virtual_pools`)
//!
//! The per-scatter synchronous join above assumes an idle fleet: every
//! idle container is equally available the instant `invoke` is called.
//! The open-loop traffic engine ([`crate::bench::load`]) instead runs N
//! concurrent queries over one absolute virtual timeline
//! ([`crate::storage::virtual_now`]), and in-flight requests must
//! *contend* for containers. With `virtual_pools: true` each container
//! carries a `free_at` timestamp on that timeline and the pool becomes a
//! small event queue:
//!
//! * a request arriving at virtual time `t` takes an idle container
//!   (`free_at ≤ t`; the most recently freed wins, ties to lowest id —
//!   deterministic, LIFO-warm like Lambda),
//! * else, if the fleet is under `max_containers` (0 = unlimited), it
//!   cold-starts a new container — cold-start probability is thereby a
//!   *function of offered load*, not a constant,
//! * else it queues on the earliest-freeing container; the wait is
//!   recorded as [`Invocation::queue_delay_s`] and in the ledger's
//!   queue-delay counters, deliberately kept out of `modeled_s` so
//!   service-time bookkeeping (hedge decisions, makespans, throughput
//!   EWMAs) stays meaningful under load.
//!
//! On release the container is stamped `free_at = virtual_now()` (entry
//! time + queue + modeled service time). Fleet mode expects same-function
//! invocations to be *serialized in real time* (the load engine processes
//! arrivals in order; the single-QA tree keeps per-function order
//! deterministic) — virtual concurrency is modeled by `free_at`, not by
//! physical thread overlap. With `virtual_pools: false` (the default)
//! acquisition is byte-identical to the pre-fleet simulator.
//!
//! # Request-lifecycle resilience ([`resilience`])
//!
//! Three further seeded fault classes extend [`ChaosConfig`]: **hangs**
//! (the invocation never returns — it burns modeled time until the
//! caller's timeout fires, or a 60 s watchdog when no timeout is set),
//! **mid-flight crashes** (the handler ran, the partial work is billed,
//! the response is lost), and **response corruption** (a byte of the
//! response frame is flipped in transit; every frame carries an FNV-1a
//! checksum computed sender-side and verified receiver-side, so the
//! corruption is *detected*, billed, and surfaced as
//! [`FaasError::CorruptResponse`]). All three draw from the same
//! SplitMix streams as the tail model, appended after the existing
//! draws, so zero-probability configs replay byte-identically.
//!
//! [`Platform::invoke_with_policy`] is the resilient entry point: it
//! debits a [`resilience::Deadline`] on the virtual clock to size each
//! attempt's timeout (`fn_timeout_s.min(deadline.remaining())`),
//! retries retryable faults under the configured
//! [`resilience::RetryPolicy`] (bounded attempts, capped exponential
//! backoff with seeded jitter — the wait advances the virtual clock and
//! is ledgered as `backoff_wait_s`), and consults one
//! [`resilience::CircuitBreaker`] per function pool, failing fast with
//! [`FaasError::CircuitOpen`] while a pool is sick instead of queueing
//! doomed work behind it. [`Platform::invoke_retrying`] is the same
//! loop with no deadline; at the default legacy policy (32 immediate
//! attempts) it reproduces the pre-resilience behavior exactly, except
//! that budget exhaustion returns a typed
//! [`FaasError::RetryBudgetExhausted`] instead of panicking.
//!
//! # Keep-alive / prewarm policies ([`keepalive`])
//!
//! With a [`KeepAliveConfig`] other than the default `NeverExpire`, a
//! released container carries a policy-assigned `[pre-warm, keep-alive]`
//! window on the virtual clock. Before every pool pick the platform
//! sweeps containers whose window has closed — dropping them (which
//! evicts their DRE-retained segment data, so the warmth loss re-bills
//! the segment I/O on the next cold start) and billing the reclaimed
//! idle span to the ledger's `idle_gb_s` bucket — and a window with a
//! non-zero pre-warm offset models a proactive re-provision: billed as a
//! cold-start-length warm-up, counted under `prewarmed_containers`, with
//! requests that then hit it warm counted under
//! `prewarm_cold_starts_avoided`. See the [`keepalive`] module docs for
//! the policy lifecycle and billing rules. At the default config none of
//! this machinery runs: acquisition and release stay byte-identical to
//! the pre-policy simulator.

pub mod dre;
pub mod keepalive;
pub mod resilience;

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::cost::compute::ComputeModel;
use crate::cost::{CostLedger, Role};
use crate::osq::simd::KernelKind;
use crate::storage::{
    advance_virtual_now, modeled_total, take_modeled_extra, take_modeled_total, virtual_now,
    SimParams,
};
use crate::util::rng::{mix64, Rng};
use dre::DreStore;
use keepalive::{KeepAliveConfig, KeepAlivePolicy};
use resilience::{BreakerConfig, CircuitBreaker, Deadline, RetryPolicy};

/// Deterministic tail-latency / fault-injection parameters. Disabled
/// (`seed: None`) means zero variance — bit-for-bit the pre-chaos
/// simulator. All draws derive from `(seed, function, invocation_id)`,
/// so identical seeds replay identical tails.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ChaosConfig {
    /// chaos stream seed; `None` disables all jitter/failures
    pub seed: Option<u64>,
    /// σ of the lognormal overhead multiplier `exp(σ·z).max(1)` applied
    /// to the cold/warm startup latency (pure tail: never < nominal)
    pub tail_sigma: f64,
    /// probability of an additional cold-start-class stall (an unlucky
    /// sandbox placement), applied on warm invocations too
    pub spike_prob: f64,
    /// magnitude of that stall in modeled seconds
    pub spike_s: f64,
    /// probability the invocation fails during init (billed, container
    /// dropped, [`FaasError::InjectedFailure`] returned)
    pub failure_prob: f64,
    /// probability the invocation hangs after init: it never returns,
    /// burning modeled time until the caller's timeout (or the 60 s
    /// watchdog) fires ([`FaasError::Timeout`])
    pub hang_prob: f64,
    /// probability the sandbox crashes mid-flight, after the handler
    /// ran: partial work billed, response lost
    /// ([`FaasError::MidflightCrash`])
    pub crash_prob: f64,
    /// probability a byte of the response frame flips in transit —
    /// caught by the FNV checksum ([`FaasError::CorruptResponse`])
    pub corrupt_prob: f64,
}

impl Default for ChaosConfig {
    fn default() -> Self {
        Self::off()
    }
}

impl ChaosConfig {
    /// Zero-variance configuration (the default).
    pub fn off() -> Self {
        Self {
            seed: None,
            tail_sigma: 0.0,
            spike_prob: 0.0,
            spike_s: 0.0,
            failure_prob: 0.0,
            hang_prob: 0.0,
            crash_prob: 0.0,
            corrupt_prob: 0.0,
        }
    }

    /// Enabled with the stock tail shape (σ = 0.35, 2% spikes of 250 ms,
    /// no failures — every fault class is opt-in via its probability).
    pub fn with_seed(seed: u64) -> Self {
        Self {
            seed: Some(seed),
            tail_sigma: 0.35,
            spike_prob: 0.02,
            spike_s: 0.25,
            ..Self::off()
        }
    }

    /// Chaos from the environment: `SQUASH_CHAOS_SEED` enables the model,
    /// `SQUASH_TAIL_SIGMA` / `SQUASH_SPIKE_PROB` / `SQUASH_FAILURE_PROB`
    /// override the shape — the CI knob that runs the whole test suite
    /// under a deterministic tail (results are invariant to modeled
    /// latency, so forcing it globally is safe).
    pub fn from_env() -> Self {
        let env_f64 = |k: &str| std::env::var(k).ok().and_then(|v| v.parse::<f64>().ok());
        match std::env::var("SQUASH_CHAOS_SEED").ok().and_then(|v| v.parse::<u64>().ok()) {
            None => Self::off(),
            Some(seed) => {
                let mut c = Self::with_seed(seed);
                if let Some(s) = env_f64("SQUASH_TAIL_SIGMA") {
                    c.tail_sigma = s;
                }
                if let Some(p) = env_f64("SQUASH_SPIKE_PROB") {
                    c.spike_prob = p;
                }
                if let Some(p) = env_f64("SQUASH_FAILURE_PROB") {
                    c.failure_prob = p;
                }
                if let Some(p) = env_f64("SQUASH_HANG_PROB") {
                    c.hang_prob = p;
                }
                if let Some(p) = env_f64("SQUASH_CRASH_PROB") {
                    c.crash_prob = p;
                }
                if let Some(p) = env_f64("SQUASH_CORRUPT_PROB") {
                    c.corrupt_prob = p;
                }
                c
            }
        }
    }

    pub fn enabled(&self) -> bool {
        self.seed.is_some()
    }
}

/// One invocation's chaos draw (see [`LatencyModel::draw`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct InvocationDraw {
    /// multiplier on the cold/warm startup latency, ≥ 1
    pub overhead_factor: f64,
    /// additional modeled stall seconds (0 when no spike drawn)
    pub spike_s: f64,
    /// invocation fails during init
    pub fail: bool,
    /// invocation hangs after init (only a timeout recovers it)
    pub hang: bool,
    /// sandbox crashes after the handler ran (billed, response lost)
    pub crash: bool,
    /// a response byte flips in transit (checksum-detected)
    pub corrupt: bool,
    /// which byte flips (drawn only when `corrupt`; 0 otherwise)
    pub corrupt_byte: u64,
}

impl InvocationDraw {
    /// The zero-variance draw.
    pub fn nominal() -> Self {
        Self {
            overhead_factor: 1.0,
            spike_s: 0.0,
            fail: false,
            hang: false,
            crash: false,
            corrupt: false,
            corrupt_byte: 0,
        }
    }
}

/// The deterministic latency/fault model: a pure function from
/// `(seed, function, invocation_id)` to an [`InvocationDraw`]. No state,
/// no clocks — replaying a run with the same seed replays the same tail.
#[derive(Clone, Debug)]
pub struct LatencyModel {
    cfg: ChaosConfig,
}

/// FNV-1a over the function name: a stable, dependency-free string hash
/// for the per-invocation draw key.
fn fnv1a64(s: &str) -> u64 {
    fnv1a64_bytes(s.as_bytes())
}

/// FNV-1a over raw bytes: the response-frame checksum. Computed
/// sender-side before transfer and verified receiver-side, so a
/// chaos-flipped byte is always *detected* rather than silently decoded.
fn fnv1a64_bytes(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

impl LatencyModel {
    pub fn new(cfg: ChaosConfig) -> Self {
        Self { cfg }
    }

    pub fn config(&self) -> &ChaosConfig {
        &self.cfg
    }

    /// Draw the chaos outcome for one invocation of `function`.
    /// `invocation_id` is the per-function sequence number, so retries
    /// and hedges get fresh, independent draws.
    pub fn draw(&self, function: &str, invocation_id: u64) -> InvocationDraw {
        let Some(seed) = self.cfg.seed else {
            return InvocationDraw::nominal();
        };
        let key = mix64(seed) ^ mix64(fnv1a64(function)) ^ mix64(0x9E37 ^ invocation_id);
        let mut rng = Rng::new(key);
        let z = rng.normal() as f64;
        let overhead_factor = (self.cfg.tail_sigma * z).exp().max(1.0);
        let spike_s = if rng.f64() < self.cfg.spike_prob { self.cfg.spike_s } else { 0.0 };
        let fail = rng.f64() < self.cfg.failure_prob;
        // the resilience fault classes draw *after* the original stream
        // (and the corrupt-byte draw is conditional), so configs with
        // these probabilities at zero replay the pre-resilience tails
        // byte-identically
        let hang = rng.f64() < self.cfg.hang_prob;
        let crash = rng.f64() < self.cfg.crash_prob;
        let corrupt = rng.f64() < self.cfg.corrupt_prob;
        let corrupt_byte = if corrupt { rng.next_u64() } else { 0 };
        InvocationDraw { overhead_factor, spike_s, fail, hang, crash, corrupt, corrupt_byte }
    }
}

/// Platform configuration (paper §5.3 defaults).
#[derive(Clone, Debug)]
pub struct FaasConfig {
    pub memory_co_mb: u32,
    pub memory_qa_mb: u32,
    pub memory_qp_mb: u32,
    /// cold start: sandbox creation + INIT phase
    pub cold_start_s: f64,
    /// warm invocation dispatch overhead
    pub warm_start_s: f64,
    /// request/response payload bandwidth
    pub payload_bandwidth_bps: f64,
    /// AWS synchronous invocation payload cap (6 MB)
    pub max_payload_bytes: usize,
    /// Data Retention Exploitation on/off (Fig 6 ablation)
    pub dre_enabled: bool,
    /// deterministic tail-latency / fault injection (off by default;
    /// `Default` honours `SQUASH_CHAOS_SEED` so CI can force it suite-wide)
    pub chaos: ChaosConfig,
    /// event-driven fleet mode: containers carry `free_at` timestamps on
    /// the absolute virtual clock and requests contend for them (see the
    /// module docs). Off by default — acquisition then stays
    /// byte-identical to the pre-fleet simulator.
    pub virtual_pools: bool,
    /// per-function container cap in fleet mode (0 = unlimited). At the
    /// cap, arrivals queue on the earliest-freeing container instead of
    /// cold-starting — the saturation knee of the load curves.
    pub max_containers: usize,
    /// per-attempt invocation timeout in modeled seconds (∞ = none, the
    /// default — timeouts then fire only from a request [`Deadline`]).
    /// `Default` honours `SQUASH_FN_TIMEOUT_S` so CI can force it.
    pub fn_timeout_s: f64,
    /// retry budget + backoff for [`Platform::invoke_with_policy`]; the
    /// default [`RetryPolicy::legacy`] reproduces the pre-resilience
    /// unbounded-feeling loop (32 immediate attempts)
    pub retry: RetryPolicy,
    /// per-function-pool circuit breaker (disabled by default)
    pub breaker: BreakerConfig,
    /// container keep-alive / prewarm policy ([`keepalive`]); the
    /// default `NeverExpire` disables the engine entirely. `Default`
    /// honours `SQUASH_KEEPALIVE` so CI can force a policy suite-wide.
    pub keepalive: KeepAliveConfig,
    /// memory-tier- and kernel-class-scaled modeled scan compute
    /// ([`crate::cost::compute::ComputeModel`]); disabled by default —
    /// modeled durations then cover startup + payload + storage only,
    /// byte-identical to the pre-compute-model platform. `Default`
    /// honours `SQUASH_COMPUTE_RPS` / `SQUASH_COMPUTE_KERNEL`.
    pub compute: ComputeModel,
}

impl Default for FaasConfig {
    fn default() -> Self {
        let fn_timeout_s = std::env::var("SQUASH_FN_TIMEOUT_S")
            .ok()
            .and_then(|v| v.parse::<f64>().ok())
            .unwrap_or(f64::INFINITY);
        Self {
            memory_co_mb: 512,
            memory_qa_mb: 1770,
            memory_qp_mb: 1770,
            cold_start_s: 0.18,
            warm_start_s: 0.006,
            payload_bandwidth_bps: 40e6,
            max_payload_bytes: 6 * 1024 * 1024,
            dre_enabled: true,
            chaos: ChaosConfig::from_env(),
            virtual_pools: false,
            max_containers: 0,
            fn_timeout_s,
            retry: RetryPolicy::legacy(),
            breaker: BreakerConfig::off(),
            keepalive: KeepAliveConfig::from_env(),
            compute: ComputeModel::from_env(),
        }
    }
}

/// A runtime container (execution environment). Its `retained` store
/// survives across invocations of the same function — the mechanism DRE
/// exploits via singleton objects.
pub struct Container {
    pub id: u64,
    pub invocations: u64,
    pub retained: DreStore,
    /// virtual time at which this container becomes idle again (fleet
    /// mode only; stays 0 when `virtual_pools` is off)
    pub free_at: f64,
    /// virtual time of the last release — the start of the current idle
    /// cycle (keep-alive policies only; stays 0 when disabled)
    pub released_at: f64,
    /// absolute start of the policy-assigned warm window. Equal to
    /// `released_at` for plain keep-alive; later for a prewarm cycle
    /// (the sandbox is dead in between). 0 when the policy is disabled,
    /// which makes every window check degenerate to "always warm".
    pub warm_from: f64,
    /// absolute end of the warm window; the sweep reclaims the container
    /// past this instant (∞ when the policy is disabled)
    pub warm_until: f64,
    /// role of the last invocation served — the memory class the
    /// keep-alive engine bills idle/prewarm time at
    pub role: Role,
}

/// Handler context: what a function sees during one invocation.
pub struct InvocationCtx<'a> {
    pub container: &'a mut Container,
    pub dre_enabled: bool,
    pub function: &'a str,
}

impl InvocationCtx<'_> {
    /// DRE read: present only on warm containers with DRE enabled.
    pub fn dre_get<T: Send + Sync + 'static>(&self, key: &str) -> Option<Arc<T>> {
        if !self.dre_enabled {
            return None;
        }
        self.container.retained.get(key)
    }

    /// DRE write (no-op when disabled, mirroring handlers that skip the
    /// singleton when the feature flag is off).
    pub fn dre_put<T: Send + Sync + 'static>(&mut self, key: &str, value: Arc<T>) {
        if self.dre_enabled {
            self.container.retained.put(key, value);
        }
    }
}

#[derive(Debug)]
pub enum FaasError {
    PayloadTooLarge(usize, usize),
    /// A chaos-injected invocation failure. Carries the modeled seconds
    /// the failed attempt consumed (billed — AWS bills failed synchronous
    /// invocations) so callers can advance their virtual clock before
    /// retrying.
    InjectedFailure { function: String, modeled_s: f64 },
    /// The attempt's timeout fired: either the invocation hung, or its
    /// modeled duration overran the remaining budget. Billed up to the
    /// timeout; the sandbox is killed, never repooled.
    Timeout { function: String, modeled_s: f64 },
    /// The sandbox crashed after the handler ran: the partial work is
    /// billed, the response is lost.
    MidflightCrash { function: String, modeled_s: f64 },
    /// The response frame failed its FNV checksum: a byte flipped in
    /// transit. Billed in full (the work ran and was transferred).
    CorruptResponse { function: String, modeled_s: f64 },
    /// The function pool's circuit breaker is open: failed fast, nothing
    /// billed, no container touched.
    CircuitOpen { function: String },
    /// The request's [`Deadline`] expired before (or between) attempts.
    /// `modeled_s` is the modeled time the failed attempts consumed.
    DeadlineExceeded { function: String, modeled_s: f64 },
    /// [`RetryPolicy::max_attempts`] retryable failures in a row — the
    /// typed replacement for the old retry-ceiling panic. Callers degrade
    /// (or error in strict mode) instead of aborting the process.
    RetryBudgetExhausted { function: String, attempts: usize, modeled_s: f64 },
}

impl FaasError {
    /// Modeled seconds the failed work consumed (0 for fail-fast and
    /// size-cap errors) — what a caller debits from its budget.
    pub fn modeled_s(&self) -> f64 {
        match self {
            FaasError::InjectedFailure { modeled_s, .. }
            | FaasError::Timeout { modeled_s, .. }
            | FaasError::MidflightCrash { modeled_s, .. }
            | FaasError::CorruptResponse { modeled_s, .. }
            | FaasError::DeadlineExceeded { modeled_s, .. }
            | FaasError::RetryBudgetExhausted { modeled_s, .. } => *modeled_s,
            FaasError::PayloadTooLarge(..) | FaasError::CircuitOpen { .. } => 0.0,
        }
    }

    /// Is a fresh attempt worth making? Transient faults are; budget,
    /// breaker, and size-cap errors are terminal.
    pub fn is_retryable(&self) -> bool {
        matches!(
            self,
            FaasError::InjectedFailure { .. }
                | FaasError::Timeout { .. }
                | FaasError::MidflightCrash { .. }
                | FaasError::CorruptResponse { .. }
        )
    }
}

impl std::fmt::Display for FaasError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FaasError::PayloadTooLarge(got, cap) => {
                write!(f, "payload of {got} bytes exceeds the synchronous invocation cap {cap}")
            }
            FaasError::InjectedFailure { function, modeled_s } => {
                write!(f, "injected invocation failure of {function} after {modeled_s:.4} modeled s")
            }
            FaasError::Timeout { function, modeled_s } => {
                write!(f, "invocation of {function} timed out after {modeled_s:.4} modeled s")
            }
            FaasError::MidflightCrash { function, modeled_s } => {
                write!(f, "{function} crashed mid-flight after {modeled_s:.4} modeled s")
            }
            FaasError::CorruptResponse { function, modeled_s } => {
                write!(
                    f,
                    "response frame from {function} failed its checksum \
                     after {modeled_s:.4} modeled s"
                )
            }
            FaasError::CircuitOpen { function } => {
                write!(f, "circuit breaker open for {function}: failing fast")
            }
            FaasError::DeadlineExceeded { function, modeled_s } => {
                write!(
                    f,
                    "deadline expired before {function} could complete \
                     ({modeled_s:.4} modeled s burned)"
                )
            }
            FaasError::RetryBudgetExhausted { function, attempts, modeled_s } => {
                write!(
                    f,
                    "{function}: retry budget exhausted after {attempts} attempts \
                     ({modeled_s:.4} modeled s burned)"
                )
            }
        }
    }
}

impl std::error::Error for FaasError {}

/// A successful invocation: the response plus its deterministic modeled
/// duration (startup + transfers + handler storage I/O + chaos jitter;
/// real compute time is excluded so the value is identical across runs
/// and time scales). Retried invocations accumulate the modeled time of
/// their failed attempts — the virtual clock a caller observes.
#[derive(Clone, Debug)]
pub struct Invocation {
    pub response: Vec<u8>,
    pub modeled_s: f64,
    /// virtual seconds this request waited for a container before its
    /// startup began (fleet mode; always 0 otherwise). Deliberately kept
    /// *out* of `modeled_s`, which remains pure service time, so hedge
    /// joins and throughput samples don't silently inflate under load.
    pub queue_delay_s: f64,
}

/// The Lambda-like platform: per-function container pools.
pub struct Platform {
    pools: Mutex<HashMap<String, Vec<Container>>>,
    /// per-function invocation sequence numbers: the deterministic
    /// `invocation_id` stream feeding [`LatencyModel::draw`]
    seq: Mutex<HashMap<String, u64>>,
    /// per-function-pool circuit breakers (populated lazily, and only
    /// when `config.breaker.enabled`)
    breakers: Mutex<HashMap<String, CircuitBreaker>>,
    /// keep-alive policy state; `None` when `config.keepalive` is the
    /// default `NeverExpire` — the pre-policy fast path
    keepalive: Option<Mutex<Box<dyn KeepAlivePolicy>>>,
    next_container: AtomicU64,
    pub config: FaasConfig,
    pub params: SimParams,
    pub ledger: Arc<CostLedger>,
    pub latency: LatencyModel,
    pub warm_invocations: AtomicU64,
    pub cold_invocations: AtomicU64,
}

/// How long a hung invocation burns on the virtual clock when the caller
/// set no timeout at all (no `fn_timeout_s`, no deadline): the platform
/// watchdog every real FaaS provider enforces (Lambda's hard cap scaled
/// to our modeled workloads).
const HANG_WATCHDOG_S: f64 = 60.0;

impl Platform {
    pub fn new(config: FaasConfig, params: SimParams, ledger: Arc<CostLedger>) -> Self {
        let latency = LatencyModel::new(config.chaos);
        let keepalive = config.keepalive.build().map(Mutex::new);
        Self {
            pools: Mutex::new(HashMap::new()),
            seq: Mutex::new(HashMap::new()),
            breakers: Mutex::new(HashMap::new()),
            keepalive,
            next_container: AtomicU64::new(0),
            config,
            params,
            ledger,
            latency,
            warm_invocations: AtomicU64::new(0),
            cold_invocations: AtomicU64::new(0),
        }
    }

    fn memory_for(&self, role: Role) -> u32 {
        match role {
            Role::Coordinator => self.config.memory_co_mb,
            Role::QueryAllocator => self.config.memory_qa_mb,
            // QP shard functions are deployed at the QP memory size: each
            // one runs the same scan kernels over a row sub-range
            Role::QueryProcessor | Role::QpShard => self.config.memory_qp_mb,
        }
    }

    /// Inject the modeled scan-compute duration for `rows` candidate
    /// rows at `role`'s memory tier with `engine_kernel` into the
    /// virtual clock (see [`crate::cost::compute::ComputeModel`]). Must
    /// be called from *inside* a handler, so the seconds drain into that
    /// invocation's `modeled_s` — and from there into throughput
    /// samples, modeled MB-seconds and latency quantiles. A no-op (zero
    /// seconds, no clock advance) when the model is disabled, keeping
    /// every default-config digest byte-identical. Returns the injected
    /// seconds.
    pub fn simulate_compute(&self, role: Role, rows: usize, engine_kernel: KernelKind) -> f64 {
        let s = self.config.compute.scan_seconds(rows, self.memory_for(role), engine_kernel);
        if s > 0.0 {
            self.params.simulate_latency(s);
        }
        s
    }

    /// Synchronously invoke `function`: acquire a container (warm if one
    /// is idle, else cold), transfer the request payload, run `handler`,
    /// transfer the response, release the container, bill everything.
    /// One attempt — a chaos-injected failure surfaces as
    /// [`FaasError::InjectedFailure`]; see [`Platform::invoke_retrying`].
    pub fn invoke<F>(
        &self,
        function: &str,
        role: Role,
        payload: &[u8],
        handler: F,
    ) -> Result<Vec<u8>, FaasError>
    where
        F: FnOnce(&mut InvocationCtx, &[u8]) -> Vec<u8>,
    {
        self.invoke_once(function, role, payload, self.config.fn_timeout_s, handler)
            .map(|inv| inv.response)
    }

    /// [`Platform::invoke_with_policy`] with no deadline — the
    /// plain-retry entry point. At the default legacy policy this is the
    /// pre-resilience behavior (32 immediate attempts, fresh draws, the
    /// failed container dropped at failure time), except budget
    /// exhaustion returns [`FaasError::RetryBudgetExhausted`] instead of
    /// panicking. The returned [`Invocation::modeled_s`] accumulates the
    /// failed attempts' modeled durations plus any backoff waits:
    /// retries are serial on the virtual clock.
    pub fn invoke_retrying<F>(
        &self,
        function: &str,
        role: Role,
        payload: &[u8],
        handler: F,
    ) -> Result<Invocation, FaasError>
    where
        F: Fn(&mut InvocationCtx, &[u8]) -> Vec<u8>,
    {
        self.invoke_with_policy(function, role, payload, Deadline::none(), handler)
    }

    /// The resilient invocation loop (see the module docs): debits
    /// `deadline` on the virtual clock to size each attempt's timeout,
    /// retries retryable faults under `config.retry` (bounded attempts,
    /// deterministic capped-exponential backoff that advances the
    /// virtual clock), and consults the function pool's circuit breaker
    /// before every attempt, failing fast while it is open.
    pub fn invoke_with_policy<F>(
        &self,
        function: &str,
        role: Role,
        payload: &[u8],
        deadline: Deadline,
        handler: F,
    ) -> Result<Invocation, FaasError>
    where
        F: Fn(&mut InvocationCtx, &[u8]) -> Vec<u8>,
    {
        let policy = self.config.retry;
        let jitter_key = mix64(self.config.chaos.seed.unwrap_or(0)) ^ mix64(fnv1a64(function));
        let mut failed_s = 0.0;
        let mut attempts = 0usize;
        for attempt in 0..policy.max_attempts.max(1) {
            let now = virtual_now();
            if deadline.expired(now) {
                return Err(FaasError::DeadlineExceeded {
                    function: function.to_string(),
                    modeled_s: failed_s,
                });
            }
            if !self.breaker_admit(function, now) {
                self.ledger.record_breaker_fast_fail();
                return Err(FaasError::CircuitOpen { function: function.to_string() });
            }
            let timeout_s = self.config.fn_timeout_s.min(deadline.remaining(now));
            attempts = attempt + 1;
            match self.invoke_once(function, role, payload, timeout_s, &handler) {
                Ok(mut inv) => {
                    self.breaker_record(function, virtual_now(), false);
                    inv.modeled_s += failed_s;
                    return Ok(inv);
                }
                Err(e) if e.is_retryable() => {
                    failed_s += e.modeled_s();
                    self.breaker_record(function, virtual_now(), true);
                    if attempt + 1 < policy.max_attempts {
                        self.ledger.record_retry();
                        let wait = policy.backoff_s(attempt + 1, jitter_key);
                        if wait > 0.0 {
                            advance_virtual_now(wait);
                            failed_s += wait;
                            self.ledger.record_backoff_wait(wait);
                        }
                    }
                }
                Err(e) => return Err(e),
            }
        }
        Err(FaasError::RetryBudgetExhausted {
            function: function.to_string(),
            attempts,
            modeled_s: failed_s,
        })
    }

    /// Breaker admission check for `function` at virtual time `now`.
    fn breaker_admit(&self, function: &str, now: f64) -> bool {
        if !self.config.breaker.enabled {
            return true;
        }
        self.breakers
            .lock()
            .unwrap()
            .entry(function.to_string())
            .or_insert_with(|| CircuitBreaker::new(self.config.breaker))
            .admit(now)
    }

    /// Record an attempt outcome with `function`'s breaker, ledgering
    /// Closed→Open transitions.
    fn breaker_record(&self, function: &str, now: f64, failed: bool) {
        if !self.config.breaker.enabled {
            return;
        }
        let mut map = self.breakers.lock().unwrap();
        let b = map
            .entry(function.to_string())
            .or_insert_with(|| CircuitBreaker::new(self.config.breaker));
        let opens_before = b.opens;
        b.record(now, failed);
        if b.opens > opens_before {
            self.ledger.record_breaker_open();
        }
    }

    /// Is `function`'s circuit breaker currently open? (tests/diagnostics)
    pub fn breaker_is_open(&self, function: &str) -> bool {
        self.breakers.lock().unwrap().get(function).map(|b| b.is_open()).unwrap_or(false)
    }

    /// Would `function`'s open breaker admit its half-open probe at
    /// virtual time `now`? A pure peek (no transition), so the hedge
    /// join can let the probe ride an already-launched duplicate instead
    /// of risking a live request — the subsequent invocation's own
    /// `breaker_admit` performs the actual Open → HalfOpen transition.
    pub fn breaker_probe_ready(&self, function: &str, now: f64) -> bool {
        self.breakers.lock().unwrap().get(function).map(|b| b.probe_ready(now)).unwrap_or(false)
    }

    /// Bill a failed attempt (AWS bills failed synchronous invocations):
    /// drain the modeled clocks, record wall + modeled runtime and the
    /// failure, and return the attempt's modeled duration.
    fn bill_failed(&self, role: Role, start: &std::time::Instant) -> f64 {
        let extra = take_modeled_extra();
        let modeled_s = take_modeled_total();
        let billed = start.elapsed().as_secs_f64() + extra;
        self.ledger.record_runtime(role, self.memory_for(role), billed);
        self.ledger.record_modeled_runtime(role, self.memory_for(role), modeled_s);
        self.ledger.record_failed_invocation();
        modeled_s
    }

    /// One attempt. `timeout_s` is the remaining budget at entry: the
    /// attempt is killed (billed up to the budget, sandbox dropped) if
    /// its modeled duration would overrun it, and a hang burns exactly
    /// the budget before the watchdog fires.
    fn invoke_once<F>(
        &self,
        function: &str,
        role: Role,
        payload: &[u8],
        timeout_s: f64,
        handler: F,
    ) -> Result<Invocation, FaasError>
    where
        F: FnOnce(&mut InvocationCtx, &[u8]) -> Vec<u8>,
    {
        if payload.len() > self.config.max_payload_bytes {
            return Err(FaasError::PayloadTooLarge(payload.len(), self.config.max_payload_bytes));
        }
        // chaos draw, keyed by the per-function invocation sequence
        let invocation_id = {
            let mut seq = self.seq.lock().unwrap();
            let c = seq.entry(function.to_string()).or_insert(0);
            let id = *c;
            *c += 1;
            id
        };
        let draw = self.latency.draw(function, invocation_id);
        // acquire container (fleet mode contends on the virtual timeline);
        // keep-alive policies sweep expired containers before every pick
        let vt = virtual_now();
        let (mut container, cold, queue_delay_s) = {
            let mut pools = self.pools.lock().unwrap();
            if self.keepalive.is_some() {
                let pool = pools.entry(function.to_string()).or_default();
                self.sweep_expired(pool, vt, function, true);
                if self.config.virtual_pools {
                    self.acquire_fleet(pool, vt)
                } else {
                    // LIFO over warm candidates — identical to the plain
                    // `pop` below whenever nothing is dead or expired
                    match pool.iter().rposition(|c| c.warm_from <= vt && vt <= c.warm_until) {
                        Some(i) => (pool.remove(i), false, 0.0),
                        None => (self.new_container(), true, 0.0),
                    }
                }
            } else if self.config.virtual_pools {
                self.acquire_fleet(pools.entry(function.to_string()).or_default(), vt)
            } else {
                match pools.get_mut(function).and_then(|v| v.pop()) {
                    Some(c) => (c, false, 0.0),
                    None => (self.new_container(), true, 0.0),
                }
            }
        };
        if queue_delay_s > 0.0 {
            advance_virtual_now(queue_delay_s);
            self.ledger.record_queue_delay(queue_delay_s);
        }
        // the budget left once the container is actually ours; a request
        // whose wait alone ate the budget abandons before startup —
        // nothing billed, the container never occupied (queue delay is
        // excluded from `modeled_s` by convention, so this carries 0)
        let run_budget = timeout_s - queue_delay_s;
        if run_budget <= 0.0 {
            self.pools.lock().unwrap().entry(function.to_string()).or_default().push(container);
            self.ledger.record_timeout();
            return Err(FaasError::Timeout { function: function.to_string(), modeled_s: 0.0 });
        }
        if let Some(ka) = &self.keepalive {
            if !cold {
                // the observed idle cycle ends now (0 for a queued
                // fleet handoff — the container never actually idled)
                let idle_s = (vt - container.released_at).max(0.0);
                ka.lock().unwrap().observe_idle(function, idle_s);
                // `vt` is the pre-queue arrival instant: a queued fleet
                // handoff onto a prewarm-pending container lands exactly
                // at the prewarm edge, so the fire check must include
                // the wait (no-op whenever queue_delay_s is 0)
                if container.warm_from > container.released_at
                    && vt + queue_delay_s >= container.warm_from
                {
                    // the prewarm fired at `warm_from`: bill the
                    // cold-start-length warm-up. The warmth between the
                    // prewarm and this hit is consumed, so (like organic
                    // warmth on every policy) it costs nothing — only
                    // wasted warmth reaches `idle_gb_s`. The rebuilt
                    // sandbox retained nothing — its DRE data died with
                    // the old one — so segment reads re-bill below even
                    // though the cold-start latency was dodged.
                    let mem = self.memory_for(role);
                    self.ledger.record_prewarm();
                    self.ledger.record_modeled_runtime(role, mem, self.config.cold_start_s);
                    self.ledger.record_prewarm_hit();
                    container.retained = DreStore::new();
                    container.invocations = 0;
                }
            }
            // in-use containers are never subject to expiry; the next
            // release stamps a fresh window
            container.warm_from = 0.0;
            container.warm_until = f64::INFINITY;
        }
        container.role = role;
        self.ledger.record_invocation(role, cold);
        if cold {
            self.cold_invocations.fetch_add(1, Ordering::Relaxed);
        } else {
            self.warm_invocations.fetch_add(1, Ordering::Relaxed);
        }

        let start = std::time::Instant::now();
        take_modeled_extra(); // reset the billing accumulator
        take_modeled_total(); // reset the virtual clock

        // startup (chaos-jittered) + request payload transfer
        let startup = if cold { self.config.cold_start_s } else { self.config.warm_start_s };
        let startup = startup * draw.overhead_factor + draw.spike_s;
        let transfer_in = payload.len() as f64 / self.config.payload_bandwidth_bps;
        self.params.simulate_latency(startup + transfer_in);
        self.ledger.record_payload(payload.len() as u64);

        // injected failure: the sandbox dies after init. AWS bills failed
        // synchronous invocations, so the duration is billed; the dead
        // container is dropped, never repooled.
        if draw.fail {
            let modeled_s = self.bill_failed(role, &start);
            return Err(FaasError::InjectedFailure { function: function.to_string(), modeled_s });
        }

        // hang: the invocation never answers. It burns the remaining
        // budget on the virtual clock (or the platform watchdog when no
        // budget was set), is billed for all of it, and only the
        // caller's timeout recovers — the sandbox is killed, not
        // repooled.
        if draw.hang {
            let burned = modeled_total();
            let stall = if run_budget.is_finite() {
                (run_budget - burned).max(0.0)
            } else {
                HANG_WATCHDOG_S
            };
            self.params.simulate_latency(stall);
            let modeled_s = self.bill_failed(role, &start);
            self.ledger.record_timeout();
            return Err(FaasError::Timeout { function: function.to_string(), modeled_s });
        }

        // INVOKE phase: run the handler
        container.invocations += 1;
        let mut ctx = InvocationCtx {
            container: &mut container,
            dre_enabled: self.config.dre_enabled,
            function,
        };
        let response = handler(&mut ctx, payload);

        // mid-flight crash: the handler's work happened and is billed
        // (AWS bills the partial duration), but the sandbox dies before
        // the response frame is produced — the response is lost and the
        // container dropped.
        if draw.crash {
            let modeled_s = self.bill_failed(role, &start);
            self.ledger.record_crash();
            return Err(FaasError::MidflightCrash { function: function.to_string(), modeled_s });
        }

        // AWS enforces the same cap on synchronous *responses*, and bills
        // the failed invocation's full duration; the produced (rejected)
        // response bytes are still counted, and the container is dropped,
        // not repooled.
        if response.len() > self.config.max_payload_bytes {
            self.ledger.record_payload(response.len() as u64);
            self.bill_failed(role, &start);
            return Err(FaasError::PayloadTooLarge(
                response.len(),
                self.config.max_payload_bytes,
            ));
        }

        // response payload transfer, framed with a sender-side FNV
        // checksum (verified below, after the wire may have corrupted it)
        let sent_checksum = fnv1a64_bytes(&response);
        let transfer_out = response.len() as f64 / self.config.payload_bandwidth_bps;
        self.params.simulate_latency(transfer_out);
        self.ledger.record_payload(response.len() as u64);

        // billing inputs: wall duration + modeled-but-unslept latencies
        let extra = take_modeled_extra();
        let modeled_s = take_modeled_total();
        let billed = start.elapsed().as_secs_f64() + extra;

        // the caller's timeout fired mid-flight: the sandbox is killed
        // at the budget and billed up to it, the finished response is
        // discarded, and the clock rewinds to the kill point (nothing
        // after the timeout is observable)
        if modeled_s > run_budget {
            advance_virtual_now(run_budget - modeled_s);
            self.ledger.record_runtime(role, self.memory_for(role), billed);
            self.ledger.record_modeled_runtime(role, self.memory_for(role), run_budget);
            self.ledger.record_failed_invocation();
            self.ledger.record_timeout();
            return Err(FaasError::Timeout {
                function: function.to_string(),
                modeled_s: run_budget,
            });
        }

        self.ledger.record_runtime(role, self.memory_for(role), billed);
        self.ledger.record_modeled_runtime(role, self.memory_for(role), modeled_s);

        // receiver-side checksum verification: chaos may have flipped a
        // byte on the wire. Detected → the fully billed invocation is a
        // failure, its frame discarded, the container dropped.
        let mut response = response;
        if draw.corrupt && !response.is_empty() {
            let idx = (draw.corrupt_byte % response.len() as u64) as usize;
            response[idx] ^= 0xFF;
        }
        if fnv1a64_bytes(&response) != sent_checksum {
            self.ledger.record_failed_invocation();
            self.ledger.record_corruption();
            return Err(FaasError::CorruptResponse {
                function: function.to_string(),
                modeled_s,
            });
        }

        // release container to the pool (warm for the next invocation);
        // fleet mode stamps when it frees up on the virtual timeline
        if self.config.virtual_pools {
            container.free_at = virtual_now();
        }
        if let Some(ka) = &self.keepalive {
            // the idle cycle starts here: ask the policy for its
            // [pre-warm, keep-alive] window, in absolute virtual time
            let released = virtual_now();
            let w = ka.lock().unwrap().window(function, released);
            let prewarm = w.prewarm_s.max(0.0);
            container.released_at = released;
            container.warm_from = released + prewarm;
            container.warm_until = released + w.keep_alive_s.max(prewarm);
        }
        self.pools.lock().unwrap().entry(function.to_string()).or_default().push(container);
        Ok(Invocation { response, modeled_s, queue_delay_s })
    }

    fn new_container(&self) -> Container {
        Container {
            id: self.next_container.fetch_add(1, Ordering::Relaxed),
            invocations: 0,
            retained: DreStore::new(),
            free_at: 0.0,
            released_at: 0.0,
            warm_from: 0.0,
            warm_until: f64::INFINITY,
            role: Role::QueryProcessor,
        }
    }

    /// Fleet-mode acquisition (see the module docs): take an idle
    /// container — the most recently freed, ties to lowest id — else cold
    /// start while under `max_containers`, else queue on the container
    /// that becomes ready first and report the wait. Fully deterministic:
    /// selection depends only on `(free_at, warm_from, id)`, never on
    /// pool insertion order.
    ///
    /// A mid-prewarm container (released, its policy window not yet open:
    /// `free_at <= vt < warm_from`) is not *idle* — the sandbox rebuild
    /// hasn't fired — but it still holds a fleet slot: the sweep already
    /// reclaimed everything expired, so every pooled container is either
    /// virtually busy or prewarm-pending and counts against the cap. Its
    /// ready instant is the prewarm edge `warm_from`, where the queued
    /// handoff consumes the warmth (billed as a prewarm in
    /// `invoke_once`). With the keep-alive engine off every window is
    /// [0, ∞) and all of this degenerates to the pre-policy behavior.
    fn acquire_fleet(&self, pool: &mut Vec<Container>, vt: f64) -> (Container, bool, f64) {
        let idle = pool
            .iter()
            .enumerate()
            .filter(|(_, c)| c.free_at <= vt && c.warm_from <= vt)
            .max_by(|(_, a), (_, b)| a.free_at.total_cmp(&b.free_at).then(b.id.cmp(&a.id)))
            .map(|(i, _)| i);
        if let Some(i) = idle {
            return (pool.swap_remove(i), false, 0.0);
        }
        let cap = self.config.max_containers;
        if cap == 0 || pool.len() < cap {
            return (self.new_container(), true, 0.0);
        }
        // every slot busy or prewarm-pending at the cap: queue on the
        // earliest-ready container (free for busy, prewarm edge for
        // pending — a busy container with a pending prewarm readies at
        // the later of the two)
        let i = pool
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| {
                let (ra, rb) = (a.free_at.max(a.warm_from), b.free_at.max(b.warm_from));
                ra.total_cmp(&rb).then(a.id.cmp(&b.id))
            })
            .map(|(i, _)| i)
            .expect("a positive cap implies a pooled container here");
        let c = pool.swap_remove(i);
        let delay = (c.free_at.max(c.warm_from) - vt).max(0.0);
        (c, false, delay)
    }

    /// Reclaim every pooled container whose keep-alive window has closed
    /// (keep-alive policies only): bill its wasted warm span, count the
    /// expiry, feed the observed idle cycle back to the policy, and drop
    /// it — which evicts its DRE-retained segment data, so the next cold
    /// start re-bills the segment reads.
    fn sweep_expired(&self, pool: &mut Vec<Container>, vt: f64, function: &str, observe: bool) {
        let mut i = 0;
        while i < pool.len() {
            if pool[i].warm_until < vt {
                let c = pool.swap_remove(i);
                self.bill_expired(&c, vt);
                self.ledger.record_expired_container();
                if observe {
                    if let Some(ka) = &self.keepalive {
                        ka.lock().unwrap().observe_idle(function, (vt - c.released_at).max(0.0));
                    }
                }
            } else {
                i += 1;
            }
        }
    }

    /// Bill one reclaimed/settled container's keep-alive cost up to
    /// `now`: the prewarm warm-up if it fired, plus the unused warm span
    /// `[warm-from, min(now, keep-alive)]` at the container's memory
    /// class. A window whose prewarm never fired (`now < warm_from`) was
    /// cancelled and costs nothing.
    fn bill_expired(&self, c: &Container, now: f64) {
        if now < c.warm_from {
            return;
        }
        let mem = self.memory_for(c.role);
        if c.warm_from > c.released_at {
            self.ledger.record_prewarm();
            self.ledger.record_modeled_runtime(c.role, mem, self.config.cold_start_s);
        }
        let idle_s = (c.warm_until.min(now) - c.warm_from).max(0.0);
        self.ledger.record_idle(idle_s * mem as f64 / 1024.0);
    }

    /// End-of-run settlement for keep-alive accounting: bill the idle
    /// warmth accrued up to `now` by every still-pooled container (the
    /// tail the sweep never sees, because no further arrival triggers
    /// it), count the already-expired ones, and drop the fleet. No-op
    /// when the policy is disabled, keeping default-config runs
    /// byte-identical to the pre-policy simulator.
    pub fn settle_idle(&self, now: f64) {
        if self.keepalive.is_none() {
            return;
        }
        let mut pools = self.pools.lock().unwrap();
        for pool in pools.values_mut() {
            for c in pool.drain(..) {
                self.bill_expired(&c, now);
                if c.warm_until < now {
                    self.ledger.record_expired_container();
                }
            }
        }
    }

    /// Is the keep-alive policy engine active (anything but the default
    /// `NeverExpire`)?
    pub fn keepalive_enabled(&self) -> bool {
        self.keepalive.is_some()
    }

    /// Predicted warmth of `function`'s pool at virtual time `vt`: does
    /// any pooled container sit free inside its policy warm window? With
    /// the policy disabled this degenerates to "any idle container
    /// pooled" — the pre-policy warmth signal. The hedge gate in
    /// [`crate::coordinator::qa`] consults this to skip hedges into
    /// predicted-cold pools.
    pub fn pool_predicted_warm(&self, function: &str, vt: f64) -> bool {
        self.pools
            .lock()
            .unwrap()
            .get(function)
            .map(|pool| {
                pool.iter().any(|c| c.free_at <= vt && c.warm_from <= vt && vt <= c.warm_until)
            })
            .unwrap_or(false)
    }

    /// Number of idle containers for a function (tests/diagnostics).
    pub fn pool_size(&self, function: &str) -> usize {
        self.pools.lock().unwrap().get(function).map(|v| v.len()).unwrap_or(0)
    }

    /// Largest single-function pool (tests/diagnostics): in fleet mode
    /// every pooled container occupies a slot, so this never exceeding
    /// `max_containers` is the fleet-cap invariant the load engine pins.
    pub fn max_pool_size(&self) -> usize {
        self.pools.lock().unwrap().values().map(|v| v.len()).max().unwrap_or(0)
    }

    /// Distinct function pools whose name starts with `prefix`
    /// (tests/diagnostics: e.g. counting the per-shard QP fleets of one
    /// partition — each shard function owns its own containers and DRE
    /// store, so the multi-function scatter must create one pool per
    /// shard, never share one).
    pub fn pools_with_prefix(&self, prefix: &str) -> usize {
        self.pools
            .lock()
            .unwrap()
            .iter()
            .filter(|(name, pool)| name.starts_with(prefix) && !pool.is_empty())
            .count()
    }

    /// Drop all containers — simulates a cold fleet / redeployment.
    pub fn reset_containers(&self) {
        self.pools.lock().unwrap().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn platform(dre: bool) -> Platform {
        let ledger = Arc::new(CostLedger::new());
        Platform::new(
            FaasConfig { dre_enabled: dre, ..Default::default() },
            SimParams::instant(),
            ledger,
        )
    }

    #[test]
    fn cold_then_warm() {
        let p = platform(true);
        for i in 0..3 {
            let r = p
                .invoke("f", Role::QueryProcessor, b"ping", |ctx, payload| {
                    assert_eq!(payload, b"ping");
                    assert_eq!(ctx.function, "f");
                    vec![i]
                })
                .unwrap();
            assert_eq!(r, vec![i]);
        }
        assert_eq!(p.cold_invocations.load(Ordering::Relaxed), 1);
        assert_eq!(p.warm_invocations.load(Ordering::Relaxed), 2);
        assert_eq!(p.pool_size("f"), 1);
    }

    #[test]
    fn simulate_compute_flows_into_modeled_runtime() {
        use crate::cost::compute::ComputeModel;
        let run = |compute: ComputeModel| {
            let ledger = Arc::new(CostLedger::new());
            let p = Platform::new(
                FaasConfig { compute, ..Default::default() },
                SimParams::instant(),
                ledger,
            );
            let mut injected = 0.0;
            p.invoke("f", Role::QueryProcessor, b"", |_, _| {
                injected = p.simulate_compute(Role::QueryProcessor, 1_000_000, KernelKind::Scalar);
                vec![]
            })
            .unwrap();
            (injected, p.ledger.modeled_mb_seconds(Role::QueryProcessor))
        };
        // default-off: zero injected seconds, pre-compute-model billing
        let (off_s, off_mbs) = run(ComputeModel::off());
        assert_eq!(off_s, 0.0);
        // enabled: the injected scan seconds land in THIS invocation's
        // modeled MB-seconds at the QP tier
        let (on_s, on_mbs) = run(ComputeModel::enabled(1.0e6));
        assert!(on_s > 0.9 && on_s < 1.1, "1M rows at ~1M rows/s: {on_s}");
        let want = 1770.0 * on_s;
        assert!(
            (on_mbs - off_mbs - want).abs() < 1e-3,
            "modeled MB-s delta {} != injected {want}",
            on_mbs - off_mbs
        );
    }

    #[test]
    fn concurrent_invocations_get_distinct_containers() {
        let p = Arc::new(platform(true));
        let barrier = Arc::new(std::sync::Barrier::new(4));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let p = p.clone();
            let b = barrier.clone();
            handles.push(std::thread::spawn(move || {
                p.invoke("g", Role::QueryAllocator, b"", |ctx, _| {
                    b.wait(); // hold all 4 containers simultaneously
                    vec![ctx.container.id as u8]
                })
                .unwrap()[0]
            }));
        }
        let mut ids: Vec<u8> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 4, "containers must not be shared concurrently");
        assert_eq!(p.cold_invocations.load(Ordering::Relaxed), 4);
        assert_eq!(p.pool_size("g"), 4);
    }

    #[test]
    fn dre_retains_across_invocations() {
        let p = platform(true);
        p.invoke("h", Role::QueryProcessor, b"", |ctx, _| {
            assert!(ctx.dre_get::<Vec<u8>>("index").is_none());
            ctx.dre_put("index", Arc::new(vec![9u8, 9, 9]));
            vec![]
        })
        .unwrap();
        p.invoke("h", Role::QueryProcessor, b"", |ctx, _| {
            let got = ctx.dre_get::<Vec<u8>>("index").expect("retained data");
            assert_eq!(*got, vec![9u8, 9, 9]);
            vec![]
        })
        .unwrap();
    }

    #[test]
    fn dre_disabled_sees_nothing() {
        let p = platform(false);
        p.invoke("h", Role::QueryProcessor, b"", |ctx, _| {
            ctx.dre_put("index", Arc::new(1u32)); // no-op
            vec![]
        })
        .unwrap();
        p.invoke("h", Role::QueryProcessor, b"", |ctx, _| {
            assert!(ctx.dre_get::<u32>("index").is_none());
            vec![]
        })
        .unwrap();
    }

    #[test]
    fn per_function_pools_are_separate() {
        // the paper names a function per partition (squash-processor-0,
        // squash-processor-1, ...) so retained indexes can't cross
        let p = platform(true);
        p.invoke("squash-processor-0", Role::QueryProcessor, b"", |ctx, _| {
            ctx.dre_put("index", Arc::new(0usize));
            vec![]
        })
        .unwrap();
        p.invoke("squash-processor-1", Role::QueryProcessor, b"", |ctx, _| {
            assert!(ctx.dre_get::<usize>("index").is_none());
            vec![]
        })
        .unwrap();
        assert_eq!(p.pool_size("squash-processor-0"), 1);
        assert_eq!(p.pool_size("squash-processor-1"), 1);
    }

    #[test]
    fn shard_functions_get_distinct_pools_and_dre_stores() {
        // the multi-function QP scatter names one function per row-range
        // shard; each must cold-start its own container and retain its
        // own copy of the partition index
        let p = platform(true);
        for s in 0..3usize {
            let f = format!("squash-processor-4-shard-{s}of3");
            p.invoke(&f, Role::QpShard, b"", |ctx, _| {
                assert!(ctx.dre_get::<usize>("partition-4").is_none());
                ctx.dre_put("partition-4", Arc::new(s));
                vec![]
            })
            .unwrap();
        }
        assert_eq!(p.pools_with_prefix("squash-processor-4-shard-"), 3);
        assert_eq!(p.pools_with_prefix("squash-processor-4"), 3);
        assert_eq!(p.pools_with_prefix("squash-processor-9"), 0);
        assert_eq!(p.cold_invocations.load(Ordering::Relaxed), 3);
        // warm reuse stays within the shard's own pool
        p.invoke("squash-processor-4-shard-1of3", Role::QpShard, b"", |ctx, _| {
            assert_eq!(*ctx.dre_get::<usize>("partition-4").unwrap(), 1);
            vec![]
        })
        .unwrap();
        assert_eq!(p.warm_invocations.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn payload_cap_enforced() {
        let p = platform(true);
        let big = vec![0u8; p.config.max_payload_bytes + 1];
        let r = p.invoke("f", Role::Coordinator, &big, |_, _| vec![]);
        assert!(matches!(r, Err(FaasError::PayloadTooLarge(_, _))));
    }

    #[test]
    fn response_cap_enforced_too() {
        let p = platform(true);
        let n = p.config.max_payload_bytes + 1;
        let r = p.invoke("f", Role::QueryProcessor, b"", move |_, _| vec![0u8; n]);
        assert!(matches!(r, Err(FaasError::PayloadTooLarge(_, _))));
        // an in-cap response still round-trips
        let ok = p.invoke("f", Role::QueryProcessor, b"", |_, _| vec![1u8]).unwrap();
        assert_eq!(ok, vec![1u8]);
    }

    #[test]
    fn billing_includes_modeled_latency_at_scale_zero() {
        let p = platform(true);
        p.invoke("f", Role::QueryProcessor, b"x", |_, _| vec![0u8; 1000]).unwrap();
        // billed runtime must include the (unslept) cold start
        let mbs = p.ledger.mb_seconds(Role::QueryProcessor);
        let billed_s = mbs / p.config.memory_qp_mb as f64;
        assert!(billed_s >= p.config.cold_start_s, "billed {billed_s}");
    }

    #[test]
    fn reset_makes_everything_cold_again() {
        let p = platform(true);
        p.invoke("f", Role::QueryProcessor, b"", |_, _| vec![]).unwrap();
        p.reset_containers();
        p.invoke("f", Role::QueryProcessor, b"", |_, _| vec![]).unwrap();
        assert_eq!(p.cold_invocations.load(Ordering::Relaxed), 2);
    }

    fn chaos_platform(chaos: ChaosConfig) -> Platform {
        let ledger = Arc::new(CostLedger::new());
        Platform::new(FaasConfig { chaos, ..Default::default() }, SimParams::instant(), ledger)
    }

    #[test]
    fn over_cap_response_is_billed_and_container_dropped() {
        // AWS bills a failed synchronous invocation for its full duration;
        // the seed returned before `record_runtime`, leaving the failure
        // free and the rejected response bytes uncounted.
        let p = chaos_platform(ChaosConfig::off());
        let n = p.config.max_payload_bytes + 1;
        let r = p.invoke("f", Role::QueryProcessor, b"req", move |_, _| vec![0u8; n]);
        assert!(matches!(r, Err(FaasError::PayloadTooLarge(_, _))));
        // duration billed at the QP memory class, at least the cold start
        let billed_s = p.ledger.mb_seconds(Role::QueryProcessor) / p.config.memory_qp_mb as f64;
        assert!(billed_s >= p.config.cold_start_s, "failed invocation billed {billed_s}s");
        // request + produced response bytes both counted
        assert_eq!(p.ledger.payload_bytes.load(Ordering::Relaxed), 3 + n as u64);
        // failure observable; the container is dropped, not repooled
        assert_eq!(p.ledger.failed_invocations.load(Ordering::Relaxed), 1);
        assert_eq!(p.pool_size("f"), 0);
        assert_eq!(p.ledger.total_invocations(), 1, "the failed attempt still counts (Eq 5)");
    }

    #[test]
    fn injected_failure_bills_drops_container_and_retry_succeeds() {
        // failure_prob 1 on the first draw is impractical; instead find a
        // seed whose first draw fails, then check the full error path
        let mut cfg = ChaosConfig::with_seed(0);
        cfg.failure_prob = 0.5;
        let seed = (0..u64::MAX)
            .find(|&s| LatencyModel::new(ChaosConfig { seed: Some(s), ..cfg }).draw("f", 0).fail)
            .unwrap();
        let p = chaos_platform(ChaosConfig { seed: Some(seed), ..cfg });
        let r = p.invoke("f", Role::QueryProcessor, b"x", |_, _| vec![1]);
        match r {
            Err(FaasError::InjectedFailure { ref function, modeled_s }) => {
                assert_eq!(function, "f");
                assert!(modeled_s >= p.config.cold_start_s, "failed init still takes time");
            }
            other => panic!("expected injected failure, got {other:?}"),
        }
        assert_eq!(p.ledger.failed_invocations.load(Ordering::Relaxed), 1);
        assert_eq!(p.pool_size("f"), 0, "failing container must be excluded from the pool");
        assert!(p.ledger.mb_seconds(Role::QueryProcessor) > 0.0, "failed invocation is billed");

        // invoke_retrying walks past the failure with fresh draws and
        // accumulates the failed attempt's modeled time
        let p2 = chaos_platform(ChaosConfig { seed: Some(seed), ..cfg });
        let inv = p2.invoke_retrying("f", Role::QueryProcessor, b"x", |_, _| vec![7]).unwrap();
        assert_eq!(inv.response, vec![7]);
        assert!(p2.ledger.failed_invocations.load(Ordering::Relaxed) >= 1);
        assert!(
            inv.modeled_s >= 2.0 * p2.config.cold_start_s,
            "virtual clock must include the failed attempt: {}",
            inv.modeled_s
        );
    }

    /// First seed whose draw for `("f", 0)` satisfies `pick`, with the
    /// fault probabilities of `cfg` — the deterministic way to force one
    /// specific fault class onto the first invocation.
    fn seed_where(cfg: ChaosConfig, pick: impl Fn(&InvocationDraw) -> bool) -> u64 {
        (0..u64::MAX)
            .find(|&s| pick(&LatencyModel::new(ChaosConfig { seed: Some(s), ..cfg }).draw("f", 0)))
            .unwrap()
    }

    #[test]
    fn new_fault_draws_do_not_perturb_the_legacy_stream() {
        // append-only draw order: enabling the new fault classes must
        // leave the original (overhead, spike, fail) stream bit-identical
        let base = ChaosConfig { failure_prob: 0.2, ..ChaosConfig::with_seed(3) };
        let plus = ChaosConfig { hang_prob: 0.3, crash_prob: 0.2, corrupt_prob: 0.5, ..base };
        let (a, b) = (LatencyModel::new(base), LatencyModel::new(plus));
        let mut fired = (false, false, false);
        for id in 0..200 {
            let x = a.draw("f", id);
            let y = b.draw("f", id);
            assert_eq!(x.overhead_factor.to_bits(), y.overhead_factor.to_bits());
            assert_eq!(x.spike_s.to_bits(), y.spike_s.to_bits());
            assert_eq!(x.fail, y.fail);
            assert!(!x.hang && !x.crash && !x.corrupt, "zero-prob draws must stay clean");
            fired = (fired.0 || y.hang, fired.1 || y.crash, fired.2 || y.corrupt);
        }
        assert!(fired.0 && fired.1 && fired.2, "the new classes must actually fire: {fired:?}");
    }

    #[test]
    fn hang_is_recovered_by_the_timeout_and_billed_up_to_it() {
        let cfg = ChaosConfig { tail_sigma: 0.0, spike_prob: 0.0, hang_prob: 0.5, ..ChaosConfig::off() };
        let cfg = ChaosConfig { seed: Some(seed_where(cfg, |d| d.hang)), ..cfg };
        let ledger = Arc::new(CostLedger::new());
        let p = Platform::new(
            FaasConfig { chaos: cfg, fn_timeout_s: 1.5, ..Default::default() },
            SimParams::instant(),
            ledger,
        );
        crate::storage::set_virtual_now(0.0);
        let r = p.invoke("f", Role::QueryProcessor, b"x", |_, _| vec![1]);
        match r {
            Err(FaasError::Timeout { ref function, modeled_s }) => {
                assert_eq!(function, "f");
                assert!((modeled_s - 1.5).abs() < 1e-9, "hang burns exactly the budget: {modeled_s}");
            }
            other => panic!("expected timeout, got {other:?}"),
        }
        assert_eq!(p.ledger.timeouts.load(Ordering::Relaxed), 1);
        assert_eq!(p.ledger.failed_invocations.load(Ordering::Relaxed), 1);
        assert_eq!(p.pool_size("f"), 0, "hung sandbox must be killed, not repooled");
        assert!((virtual_now() - 1.5).abs() < 1e-9, "the wait happened on the virtual clock");
        assert!(p.ledger.mb_seconds(Role::QueryProcessor) > 0.0, "billed until the kill");

        // with no timeout anywhere, the platform watchdog bounds the burn
        let ledger = Arc::new(CostLedger::new());
        let p = Platform::new(
            FaasConfig { chaos: cfg, ..Default::default() },
            SimParams::instant(),
            ledger,
        );
        match p.invoke("f", Role::QueryProcessor, b"x", |_, _| vec![1]) {
            Err(FaasError::Timeout { modeled_s, .. }) => {
                assert!(modeled_s >= HANG_WATCHDOG_S, "watchdog burn: {modeled_s}")
            }
            other => panic!("expected watchdog timeout, got {other:?}"),
        }
    }

    #[test]
    fn midflight_crash_bills_partial_work_and_loses_the_response() {
        let cfg = ChaosConfig { crash_prob: 0.5, ..ChaosConfig::with_seed(0) };
        let cfg = ChaosConfig { seed: Some(seed_where(cfg, |d| d.crash && !d.fail)), ..cfg };
        let p = chaos_platform(cfg);
        let ran = std::sync::atomic::AtomicBool::new(false);
        let r = p.invoke("f", Role::QueryProcessor, b"req", |_, _| {
            ran.store(true, Ordering::Relaxed);
            vec![0u8; 100]
        });
        match r {
            Err(FaasError::MidflightCrash { ref function, modeled_s }) => {
                assert_eq!(function, "f");
                assert!(modeled_s >= p.config.cold_start_s, "partial work takes time");
            }
            other => panic!("expected crash, got {other:?}"),
        }
        assert!(ran.load(Ordering::Relaxed), "the handler DID run before the crash");
        assert_eq!(p.ledger.crashes.load(Ordering::Relaxed), 1);
        assert_eq!(p.ledger.failed_invocations.load(Ordering::Relaxed), 1);
        assert_eq!(p.pool_size("f"), 0);
        // the lost response's bytes never hit the wire: only the request
        assert_eq!(p.ledger.payload_bytes.load(Ordering::Relaxed), 3);
        assert!(p.ledger.mb_seconds(Role::QueryProcessor) > 0.0, "partial work is billed");
    }

    #[test]
    fn corrupt_response_is_detected_by_the_frame_checksum() {
        let cfg = ChaosConfig { corrupt_prob: 0.5, ..ChaosConfig::with_seed(0) };
        let cfg = ChaosConfig { seed: Some(seed_where(cfg, |d| d.corrupt && !d.fail)), ..cfg };
        let p = chaos_platform(cfg);
        let r = p.invoke("f", Role::QueryProcessor, b"req", |_, _| vec![7u8; 64]);
        match r {
            Err(FaasError::CorruptResponse { ref function, modeled_s }) => {
                assert_eq!(function, "f");
                assert!(modeled_s > 0.0);
            }
            other => panic!("expected corruption, got {other:?}"),
        }
        assert_eq!(p.ledger.corruptions.load(Ordering::Relaxed), 1);
        assert_eq!(p.ledger.failed_invocations.load(Ordering::Relaxed), 1);
        // the corrupted frame WAS transferred: request + response counted
        assert_eq!(p.ledger.payload_bytes.load(Ordering::Relaxed), 3 + 64);
        assert_eq!(p.pool_size("f"), 0, "suspect container dropped");
        // a retry with a clean draw delivers the uncorrupted frame
        let inv = p.invoke_retrying("f", Role::QueryProcessor, b"req", |_, _| vec![7u8; 64]);
        assert_eq!(inv.unwrap().response, vec![7u8; 64]);
    }

    #[test]
    fn modeled_overrun_of_the_timeout_kills_the_sandbox_at_the_budget() {
        let ledger = Arc::new(CostLedger::new());
        let p = Platform::new(
            FaasConfig { fn_timeout_s: 0.01, ..Default::default() },
            SimParams::instant(),
            ledger,
        );
        crate::storage::set_virtual_now(0.0);
        // the 0.18 s cold start alone overruns a 10 ms budget
        let r = p.invoke("f", Role::QueryProcessor, b"x", |_, _| vec![1]);
        match r {
            Err(FaasError::Timeout { modeled_s, .. }) => {
                assert!((modeled_s - 0.01).abs() < 1e-12, "billed up to the budget: {modeled_s}")
            }
            other => panic!("expected overrun timeout, got {other:?}"),
        }
        assert!((virtual_now() - 0.01).abs() < 1e-12, "clock rewound to the kill point");
        assert_eq!(p.ledger.timeouts.load(Ordering::Relaxed), 1);
        let billed = p.ledger.modeled_mb_seconds(Role::QueryProcessor) / p.config.memory_qp_mb as f64;
        assert!((billed - 0.01).abs() < 1e-6, "modeled billing clamped to the budget: {billed}");
        assert_eq!(p.pool_size("f"), 0);
    }

    #[test]
    fn queue_wait_that_eats_the_deadline_abandons_unbilled() {
        use crate::storage::set_virtual_now;
        let ledger = Arc::new(CostLedger::new());
        let p = Platform::new(
            FaasConfig { virtual_pools: true, max_containers: 1, ..Default::default() },
            SimParams::instant(),
            ledger,
        );
        set_virtual_now(0.0);
        p.invoke("f", Role::QueryProcessor, b"x", |_, _| vec![1]).unwrap();
        // a second arrival at t=0 must wait ≥ the 0.18 s cold start — far
        // past its 50 ms deadline — so it abandons in the queue and the
        // retry loop then sees the deadline expired
        set_virtual_now(0.0);
        let r = p.invoke_with_policy(
            "f",
            Role::QueryProcessor,
            b"x",
            Deadline::at(0.05),
            |_, _| vec![2],
        );
        assert!(matches!(r, Err(FaasError::DeadlineExceeded { .. })), "got {r:?}");
        assert_eq!(p.ledger.timeouts.load(Ordering::Relaxed), 1);
        assert_eq!(p.ledger.failed_invocations.load(Ordering::Relaxed), 0, "nothing billed");
        assert_eq!(p.ledger.total_invocations(), 1, "the abandoned wait is not an invocation");
        assert_eq!(p.pool_size("f"), 1, "the container was never occupied");
    }

    #[test]
    fn retry_budget_exhaustion_is_a_typed_error_not_a_panic() {
        let cfg = ChaosConfig { failure_prob: 1.0, ..ChaosConfig::with_seed(5) };
        let ledger = Arc::new(CostLedger::new());
        let p = Platform::new(
            FaasConfig {
                chaos: cfg,
                retry: RetryPolicy { max_attempts: 3, ..RetryPolicy::legacy() },
                ..Default::default()
            },
            SimParams::instant(),
            ledger,
        );
        let err = p.invoke_retrying("f", Role::QueryProcessor, b"x", |_, _| vec![]).unwrap_err();
        match err {
            FaasError::RetryBudgetExhausted { ref function, attempts, modeled_s } => {
                assert_eq!(function, "f");
                assert_eq!(attempts, 3);
                assert!(modeled_s >= 3.0 * p.config.cold_start_s, "all attempts burned time");
            }
            other => panic!("expected budget exhaustion, got {other:?}"),
        }
        assert_eq!(p.ledger.retries.load(Ordering::Relaxed), 2, "2 retries after the first try");
        assert_eq!(p.ledger.failed_invocations.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn backoff_waits_advance_the_virtual_clock_and_are_ledgered() {
        use crate::storage::set_virtual_now;
        let cfg = ChaosConfig { failure_prob: 1.0, ..ChaosConfig::with_seed(5) };
        let retry = RetryPolicy {
            max_attempts: 3,
            base_backoff_s: 0.1,
            backoff_multiplier: 2.0,
            max_backoff_s: 10.0,
            jitter: 0.0,
        };
        let ledger = Arc::new(CostLedger::new());
        let p = Platform::new(
            FaasConfig { chaos: cfg, retry, ..Default::default() },
            SimParams::instant(),
            ledger,
        );
        set_virtual_now(0.0);
        let err = p.invoke_retrying("f", Role::QueryProcessor, b"x", |_, _| vec![]).unwrap_err();
        let modeled_s = match err {
            FaasError::RetryBudgetExhausted { modeled_s, .. } => modeled_s,
            other => panic!("expected budget exhaustion, got {other:?}"),
        };
        // waits of 0.1 then 0.2 s between the three attempts
        assert!((p.ledger.backoff_wait_s() - 0.3).abs() < 1e-6);
        assert!(modeled_s > 0.3, "burned time includes the backoff waits");
        assert!((virtual_now() - modeled_s).abs() < 1e-9, "waits happened on the clock");
    }

    #[test]
    fn breaker_opens_fails_fast_and_probes_per_function() {
        use crate::storage::set_virtual_now;
        let cfg = ChaosConfig { failure_prob: 1.0, ..ChaosConfig::with_seed(9) };
        let breaker = BreakerConfig {
            enabled: true,
            window: 4,
            min_samples: 2,
            failure_threshold: 0.5,
            open_s: 5.0,
        };
        let ledger = Arc::new(CostLedger::new());
        let p = Platform::new(
            FaasConfig { chaos: cfg, breaker, ..Default::default() },
            SimParams::instant(),
            ledger,
        );
        set_virtual_now(0.0);
        // attempts 1+2 fail and trip the breaker; attempt 3 is rejected
        let err = p.invoke_retrying("f", Role::QueryProcessor, b"x", |_, _| vec![]).unwrap_err();
        assert!(matches!(err, FaasError::CircuitOpen { .. }), "got {err:?}");
        assert!(p.breaker_is_open("f"));
        assert!(!p.breaker_is_open("g"), "breakers are per function pool");
        assert_eq!(p.ledger.breaker_open_events.load(Ordering::Relaxed), 1);
        assert_eq!(p.ledger.breaker_fast_fails.load(Ordering::Relaxed), 1);
        assert_eq!(p.ledger.failed_invocations.load(Ordering::Relaxed), 2, "fast fail bills nothing");
        // past open_s, half-open admits exactly one probe; it fails for
        // real, re-trips the breaker, and the next attempt fast-fails
        set_virtual_now(10.0);
        let err = p.invoke_retrying("f", Role::QueryProcessor, b"x", |_, _| vec![]).unwrap_err();
        assert!(matches!(err, FaasError::CircuitOpen { .. }), "got {err:?}");
        assert!(p.breaker_is_open("f"), "failed probe re-opens");
        assert_eq!(p.ledger.breaker_open_events.load(Ordering::Relaxed), 2);
        assert_eq!(p.ledger.breaker_fast_fails.load(Ordering::Relaxed), 2);
        assert_eq!(p.ledger.failed_invocations.load(Ordering::Relaxed), 3, "one probe ran");
    }

    #[test]
    fn latency_model_is_deterministic_and_pure_tail() {
        let m = LatencyModel::new(ChaosConfig { failure_prob: 0.1, ..ChaosConfig::with_seed(42) });
        for id in 0..200 {
            let a = m.draw("squash-processor-3", id);
            let b = m.draw("squash-processor-3", id);
            assert_eq!(a, b, "same (seed, function, id) must replay the same draw");
            assert!(a.overhead_factor >= 1.0, "jitter is pure-tail");
            assert!(a.spike_s >= 0.0);
        }
        // different functions and ids decorrelate
        let a = m.draw("squash-processor-3", 0);
        let b = m.draw("squash-processor-4", 0);
        let c = m.draw("squash-processor-3", 1);
        assert!(a != b || a != c, "draws must vary across functions/ids");
        // disabled model is exactly nominal
        let off = LatencyModel::new(ChaosConfig::off());
        assert_eq!(off.draw("f", 9), InvocationDraw::nominal());
    }

    #[test]
    fn chaos_jitter_only_adds_modeled_latency() {
        // pure-tail property: for the same invocation sequence, chaos
        // billing ≥ zero-variance billing
        let quiet = chaos_platform(ChaosConfig::off());
        let noisy = chaos_platform(ChaosConfig {
            tail_sigma: 0.8,
            spike_prob: 0.5,
            spike_s: 1.0,
            ..ChaosConfig::with_seed(7)
        });
        for _ in 0..20 {
            quiet.invoke("f", Role::QueryProcessor, b"p", |_, _| vec![0]).unwrap();
            noisy.invoke("f", Role::QueryProcessor, b"p", |_, _| vec![0]).unwrap();
        }
        let q = quiet.ledger.mb_seconds(Role::QueryProcessor);
        let n = noisy.ledger.mb_seconds(Role::QueryProcessor);
        assert!(n >= q, "chaos must only add latency: {n} < {q}");
        assert!(n > q, "σ=0.8 + 50% spikes over 20 invocations must show up");
    }

    fn fleet_platform(cap: usize) -> Platform {
        let ledger = Arc::new(CostLedger::new());
        Platform::new(
            FaasConfig { virtual_pools: true, max_containers: cap, ..Default::default() },
            SimParams::instant(),
            ledger,
        )
    }

    #[test]
    fn fleet_mode_queues_at_the_container_cap() {
        use crate::storage::set_virtual_now;
        let p = fleet_platform(1);
        set_virtual_now(0.0);
        let first = p.invoke_retrying("f", Role::QueryProcessor, b"x", |_, _| vec![1]).unwrap();
        assert_eq!(first.queue_delay_s, 0.0);
        let busy_until = virtual_now();
        assert!(busy_until >= p.config.cold_start_s);
        // a second arrival at t=0 finds the only container busy until
        // `busy_until` and must wait exactly that long
        set_virtual_now(0.0);
        let second = p.invoke_retrying("f", Role::QueryProcessor, b"x", |_, _| vec![2]).unwrap();
        assert_eq!(second.queue_delay_s.to_bits(), busy_until.to_bits());
        assert_eq!(p.cold_invocations.load(Ordering::Relaxed), 1);
        assert_eq!(p.warm_invocations.load(Ordering::Relaxed), 1);
        // the wait is ledgered separately and never inflates service time
        assert!((p.ledger.queue_delay_s() - busy_until).abs() < 1e-5);
        assert!(second.modeled_s < p.config.cold_start_s, "queued run must start warm");
        assert!(virtual_now() > busy_until, "the wait advances the virtual clock");
    }

    #[test]
    fn fleet_mode_cold_starts_scale_with_offered_load_below_cap() {
        use crate::storage::set_virtual_now;
        let p = fleet_platform(2);
        set_virtual_now(0.0);
        p.invoke("f", Role::QueryProcessor, b"", |_, _| vec![]).unwrap();
        // a concurrent arrival (t = 0 again) finds the fleet busy but
        // under the cap: offered load itself forces the second cold start
        set_virtual_now(0.0);
        let inv = p.invoke_retrying("f", Role::QueryProcessor, b"", |_, _| vec![]).unwrap();
        assert_eq!(inv.queue_delay_s, 0.0);
        assert_eq!(p.cold_invocations.load(Ordering::Relaxed), 2);
        // once both are idle again, arrivals reuse containers warm
        let now = virtual_now();
        set_virtual_now(now + 1.0);
        p.invoke("f", Role::QueryProcessor, b"", |_, _| vec![]).unwrap();
        assert_eq!(p.cold_invocations.load(Ordering::Relaxed), 2);
        assert_eq!(p.warm_invocations.load(Ordering::Relaxed), 1);
        assert_eq!(p.pool_size("f"), 2);
    }

    #[test]
    fn fleet_cap_counts_mid_prewarm_containers() {
        let p = fleet_platform(1);
        let mut c = p.new_container();
        c.released_at = 0.0;
        c.free_at = 0.0;
        c.warm_from = 1.0;
        c.warm_until = 2.0;
        let id = c.id;
        let mut pool = vec![c];
        // at vt=0.5 the only container is mid-prewarm: not idle (its
        // window hasn't opened yet) but it still holds the single fleet
        // slot, so the arrival queues on the prewarm edge instead of
        // cold-starting a second container past the cap
        let (picked, cold, delay) = p.acquire_fleet(&mut pool, 0.5);
        assert!(!cold, "a mid-prewarm container occupies the only fleet slot");
        assert_eq!(picked.id, id);
        assert_eq!(delay.to_bits(), 0.5f64.to_bits(), "ready at the warm_from=1.0 edge");
        assert!(pool.is_empty());
    }

    #[test]
    fn fleet_queued_prewarm_handoff_bills_the_warmup() {
        use crate::storage::set_virtual_now;
        let ledger = Arc::new(CostLedger::new());
        let p = Platform::new(
            FaasConfig {
                virtual_pools: true,
                max_containers: 1,
                keepalive: KeepAliveConfig::FixedTtl { keep_alive_s: 10.0 },
                ..Default::default()
            },
            SimParams::instant(),
            ledger,
        );
        // hand-craft the single slot as prewarm-pending: released at
        // t=0, sandbox rebuild due at t=1, window open through t=10
        let mut c = p.new_container();
        c.released_at = 0.0;
        c.free_at = 0.0;
        c.warm_from = 1.0;
        c.warm_until = 10.0;
        p.pools.lock().unwrap().insert("f".to_string(), vec![c]);
        set_virtual_now(0.5);
        let inv = p.invoke_retrying("f", Role::QueryProcessor, b"", |_, _| vec![]).unwrap();
        // the wait runs to the prewarm edge, and the handoff consumes
        // the prewarmed warmth: no cold start, warm-up billed
        assert_eq!(inv.queue_delay_s.to_bits(), 0.5f64.to_bits());
        assert_eq!(p.cold_invocations.load(Ordering::Relaxed), 0);
        assert_eq!(p.warm_invocations.load(Ordering::Relaxed), 1);
        assert_eq!(p.ledger.prewarmed_containers.load(Ordering::Relaxed), 1);
        assert_eq!(p.ledger.prewarm_cold_starts_avoided.load(Ordering::Relaxed), 1);
        assert!((p.ledger.queue_delay_s() - 0.5).abs() < 1e-9);
        let warmup_mbs = p.config.cold_start_s * p.config.memory_qp_mb as f64;
        assert!(
            p.ledger.modeled_mb_seconds(Role::QueryProcessor) >= warmup_mbs,
            "the consumed prewarm must bill its cold-start-length warm-up"
        );
        assert_eq!(p.max_pool_size(), 1, "the cap held through the prewarm window");
    }

    #[test]
    fn modeled_duration_is_deterministic_across_runs() {
        let run = || {
            let p = chaos_platform(ChaosConfig::with_seed(11));
            let mut total = 0.0;
            for _ in 0..10 {
                total += p
                    .invoke_retrying("g", Role::QueryAllocator, b"abc", |_, _| vec![0u8; 100])
                    .unwrap()
                    .modeled_s;
            }
            total
        };
        assert_eq!(run().to_bits(), run().to_bits(), "virtual clock must replay bit-identically");
    }

    fn keepalive_platform(ka: KeepAliveConfig) -> Platform {
        let ledger = Arc::new(CostLedger::new());
        Platform::new(
            FaasConfig { keepalive: ka, ..Default::default() },
            SimParams::instant(),
            ledger,
        )
    }

    #[test]
    fn keepalive_fixed_ttl_expires_bills_idle_and_evicts_dre() {
        use crate::storage::set_virtual_now;
        let p = keepalive_platform(KeepAliveConfig::FixedTtl { keep_alive_s: 1.0 });
        set_virtual_now(0.0);
        p.invoke("f", Role::QueryProcessor, b"", |ctx, _| {
            ctx.dre_put("seg", Arc::new(7u32));
            vec![]
        })
        .unwrap();
        // within the TTL: a warm hit, retention free, DRE intact
        let released = virtual_now();
        set_virtual_now(released + 0.5);
        p.invoke("f", Role::QueryProcessor, b"", |ctx, _| {
            assert!(ctx.dre_get::<u32>("seg").is_some(), "retained within the TTL");
            vec![]
        })
        .unwrap();
        assert_eq!(p.ledger.idle_gb_s(), 0.0, "organic warmth is free");
        // past the TTL: the sweep reclaims the container, bills its full
        // warm window, and the arrival cold-starts with an empty store
        let released = virtual_now();
        set_virtual_now(released + 5.0);
        p.invoke("f", Role::QueryProcessor, b"", |ctx, _| {
            assert!(ctx.dre_get::<u32>("seg").is_none(), "expiry evicts DRE");
            vec![]
        })
        .unwrap();
        assert_eq!(p.cold_invocations.load(Ordering::Relaxed), 2);
        assert_eq!(p.warm_invocations.load(Ordering::Relaxed), 1);
        assert_eq!(p.ledger.expired_containers.load(Ordering::Relaxed), 1);
        let want = 1.0 * p.config.memory_qp_mb as f64 / 1024.0;
        assert!((p.ledger.idle_gb_s() - want).abs() < 1e-6, "got {}", p.ledger.idle_gb_s());
    }

    #[test]
    fn keepalive_huge_ttl_is_byte_identical_to_disabled() {
        use crate::storage::set_virtual_now;
        let run = |ka: KeepAliveConfig| {
            let p = keepalive_platform(ka);
            set_virtual_now(0.0);
            for i in 0..6u8 {
                let t = virtual_now();
                set_virtual_now(t + 0.25 * i as f64);
                p.invoke("f", Role::QueryProcessor, &[i], |_, payload| payload.to_vec())
                    .unwrap();
            }
            (p.ledger.chaos_summary(), virtual_now().to_bits())
        };
        let base = run(KeepAliveConfig::NeverExpire);
        let ttl = run(KeepAliveConfig::FixedTtl { keep_alive_s: 1e9 });
        assert_eq!(base, ttl, "a TTL longer than the run must be inert");
    }

    #[test]
    fn keepalive_hybrid_prewarm_bills_warmup_and_evicts_dre() {
        use crate::storage::set_virtual_now;
        // a fallback TTL above the 0.5 s cycle gap so the warm-up hits
        // stay warm while the histogram learns
        let p = keepalive_platform(KeepAliveConfig::Hybrid(keepalive::HybridConfig {
            fallback_ttl_s: 2.0,
            ..Default::default()
        }));
        set_virtual_now(0.0);
        p.invoke("f", Role::QueryProcessor, b"", |ctx, _| {
            ctx.dre_put("seg", Arc::new(1u8));
            vec![]
        })
        .unwrap();
        // feed `min_samples` identical ~0.5 s idle cycles; while learning
        // the 2 s fallback TTL keeps every hit warm and free
        for _ in 0..8 {
            let released = virtual_now();
            set_virtual_now(released + 0.5);
            p.invoke("f", Role::QueryProcessor, b"", |_, _| vec![]).unwrap();
        }
        assert_eq!(p.cold_invocations.load(Ordering::Relaxed), 1);
        assert_eq!(p.ledger.prewarmed_containers.load(Ordering::Relaxed), 0);
        // the trusted histogram now predicts a prewarm edge below the
        // 0.5 s mode: the next arrival lands past it — no cold-start
        // latency, but the rebuilt sandbox lost its DRE data and the
        // warm-up itself was billed as a cold-start-length modeled run
        let modeled_before = p.ledger.modeled_mb_seconds(Role::QueryProcessor);
        let released = virtual_now();
        set_virtual_now(released + 0.5);
        p.invoke("f", Role::QueryProcessor, b"", |ctx, _| {
            assert!(ctx.dre_get::<u8>("seg").is_none(), "prewarm rebuilt the sandbox");
            vec![]
        })
        .unwrap();
        assert_eq!(p.cold_invocations.load(Ordering::Relaxed), 1, "latency-warm via prewarm");
        assert_eq!(p.ledger.prewarmed_containers.load(Ordering::Relaxed), 1);
        assert_eq!(p.ledger.prewarm_cold_starts_avoided.load(Ordering::Relaxed), 1);
        let warmup_mbs = p.config.cold_start_s * p.config.memory_qp_mb as f64;
        assert!(
            p.ledger.modeled_mb_seconds(Role::QueryProcessor) - modeled_before >= warmup_mbs,
            "the prewarm warm-up is billed"
        );
        assert_eq!(p.ledger.idle_gb_s(), 0.0, "consumed warmth is free, like organic warmth");
    }

    #[test]
    fn keepalive_settle_idle_bills_the_end_of_run_tail() {
        use crate::storage::set_virtual_now;
        let p = keepalive_platform(KeepAliveConfig::FixedTtl { keep_alive_s: 1.0 });
        set_virtual_now(0.0);
        p.invoke("f", Role::QueryProcessor, b"", |_, _| vec![]).unwrap();
        let released = virtual_now();
        // the run ends 0.4 s later: the container is still warm — settle
        // bills the 0.4 s tail (not the full TTL) and drains the fleet
        p.settle_idle(released + 0.4);
        assert_eq!(p.ledger.expired_containers.load(Ordering::Relaxed), 0);
        let want = 0.4 * p.config.memory_qp_mb as f64 / 1024.0;
        assert!((p.ledger.idle_gb_s() - want).abs() < 1e-6, "got {}", p.ledger.idle_gb_s());
        assert_eq!(p.pool_size("f"), 0, "settlement drains the pools");
    }

    #[test]
    fn keepalive_pool_predicted_warm_tracks_the_policy_window() {
        use crate::storage::set_virtual_now;
        let p = keepalive_platform(KeepAliveConfig::FixedTtl { keep_alive_s: 1.0 });
        assert!(p.keepalive_enabled());
        assert!(!p.pool_predicted_warm("f", 0.0), "no container yet");
        set_virtual_now(0.0);
        p.invoke("f", Role::QueryProcessor, b"", |_, _| vec![]).unwrap();
        let released = virtual_now();
        assert!(p.pool_predicted_warm("f", released + 0.5), "inside the TTL");
        assert!(!p.pool_predicted_warm("f", released + 1.5), "past the TTL");
        let q = keepalive_platform(KeepAliveConfig::NeverExpire);
        assert!(!q.keepalive_enabled(), "NeverExpire means engine off");
    }
}
