//! Data Retention Exploitation store (paper §3.2).
//!
//! AWS Lambda re-uses execution environments across invocations; any
//! state parked in a global ("singleton class" in the paper's Python)
//! survives. `DreStore` is that global area: a typed KV map living inside
//! a simulated container. QA/QP handlers check it before fetching index
//! files from object storage, eliminating redundant I/O on warm starts.

use std::any::Any;
use std::collections::HashMap;
use std::sync::Arc;

/// Type-erased retained-data store (one per container).
#[derive(Default)]
pub struct DreStore {
    map: HashMap<String, Arc<dyn Any + Send + Sync>>,
}

impl DreStore {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn get<T: Send + Sync + 'static>(&self, key: &str) -> Option<Arc<T>> {
        self.map.get(key).and_then(|v| v.clone().downcast::<T>().ok())
    }

    pub fn put<T: Send + Sync + 'static>(&mut self, key: &str, value: Arc<T>) {
        self.map.insert(key.to_string(), value);
    }

    pub fn contains(&self, key: &str) -> bool {
        self.map.contains_key(key)
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn typed_roundtrip() {
        let mut s = DreStore::new();
        s.put("a", Arc::new(vec![1u32, 2]));
        s.put("b", Arc::new("text".to_string()));
        assert_eq!(*s.get::<Vec<u32>>("a").unwrap(), vec![1, 2]);
        assert_eq!(*s.get::<String>("b").unwrap(), "text");
        assert!(s.get::<u64>("a").is_none(), "wrong type yields None");
        assert!(s.get::<u32>("missing").is_none());
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn overwrite() {
        let mut s = DreStore::new();
        s.put("k", Arc::new(1u32));
        s.put("k", Arc::new(2u32));
        assert_eq!(*s.get::<u32>("k").unwrap(), 2);
        assert_eq!(s.len(), 1);
    }
}
