//! Keep-alive / prewarm policy engine: when does an idle container die,
//! and when is it proactively resurrected?
//!
//! The seed platform kept every released container warm forever, so
//! container retention — the mechanism Data Retention Exploitation
//! (paper §3.2) monetizes — was free and invisible: no cold-start-rate
//! vs. idle-cost trade-off existed to measure. This module makes
//! retention a *policy*, evaluated on the shared virtual clock
//! ([`crate::storage::virtual_now`]), with the cost side billed to the
//! ledger.
//!
//! # Policy lifecycle
//!
//! Every policy answers one question per idle cycle. When a container is
//! released at virtual time `r`, [`KeepAlivePolicy::window`] returns an
//! [`IdleWindow`] `{prewarm_s, keep_alive_s}` of offsets from `r`:
//!
//! * the container is **warm** (reusable) during
//!   `[r + prewarm_s, r + keep_alive_s]`,
//! * with `prewarm_s > 0` the sandbox is torn down at `r` and
//!   *re-provisioned* at `r + prewarm_s` — a **prewarm**. The rebuilt
//!   sandbox starts empty: its DRE-retained segment data is gone, so the
//!   next invocation re-reads (and re-bills) its segments even though it
//!   dodges the cold-start latency,
//! * past `r + keep_alive_s` the container is **expired**: the platform
//!   sweeps it before each pool pick, drops it (evicting its
//!   [`crate::faas::dre::DreStore`]), and bills the reclaimed window.
//!
//! When the next invocation of the function arrives, the platform feeds
//! the *observed* idle time back via [`KeepAlivePolicy::observe_idle`] —
//! the learning signal for the histogram policy.
//!
//! # Prewarm / idle billing
//!
//! Lambda does not charge for organic warmth between invocations, so a
//! keep-alive window that a warm hit consumes is free — exactly the
//! pre-policy behavior. What the policy engine *does* bill, to the new
//! ledger buckets:
//!
//! * `idle_gb_s` — GB-seconds of warmth the policy paid for and nobody
//!   used: the full `[warm-from, keep-alive]` span of every *expired*
//!   container, and (via [`crate::faas::Platform::settle_idle`]) the
//!   accrued warm span of containers still pooled when a run ends.
//!   Warmth that a hit consumes is free on every policy — prewarmed or
//!   organic — so the bucket is a pure waste metric and the Pareto axes
//!   stay comparable across policies,
//! * `prewarmed_containers` — prewarms that actually executed, each
//!   billed as a cold-start-length modeled warm-up at the function's
//!   memory,
//! * `prewarm_cold_starts_avoided` — prewarmed containers that a request
//!   then hit warm,
//! * `expired_containers` — containers reclaimed by the sweep.
//!
//! An un-fired prewarm (the next request arrived before `prewarm_s`
//! elapsed) is cancelled and costs nothing.
//!
//! # Policies
//!
//! * [`NeverExpire`] — the default; byte-identical to the pre-policy
//!   platform (no sweeps, no stamps, no billing).
//! * [`FixedTtl`] — warm for a constant `keep_alive_s` after release,
//!   never prewarms. The classic provider policy.
//! * [`HybridHistogram`] — the "Serverless in the Wild" policy: a
//!   per-function histogram of observed idle times predicts a
//!   `[pre-warm, keep-alive]` window per idle cycle (head-quantile minus
//!   a margin, tail-quantile plus a margin, clamped to bracket the
//!   histogram's mode bin). Out-of-bounds idle times are tracked by
//!   head/tail counters; when the head or tail OOB share exceeds
//!   `oob_fraction`, when fewer than `min_samples` cycles have been
//!   seen, or when the in-bin distribution is too dispersed
//!   (coefficient of variation above `cv_threshold`), the policy falls
//!   back to a plain fixed-TTL window (`fallback_ttl_s`, no prewarm).
//!
//! # `BENCH_keepalive.json` schema
//!
//! [`crate::bench::keepalive`] sweeps policy × TTL × arrival profile and
//! writes one Pareto point per policy:
//!
//! ```json
//! {
//!   "suite": "keepalive",
//!   "seed": 42, "qps": 10.0, "queries": 96, "profile": "poisson",
//!   "points": [
//!     {"policy": "ttl:0.5", "invocations": 0, "cold_starts": 0,
//!      "cold_rate": 0.0, "idle_gb_s": 0.0, "expired": 0,
//!      "prewarmed": 0, "prewarm_hits": 0, "hedges_skipped_cold": 0,
//!      "queued": 0, "p50_s": 0.0, "p99_s": 0.0, "modeled_gb_s": 0.0}
//!   ]
//! }
//! ```
//!
//! `cold_rate` is `cold_starts / invocations`, `idle_gb_s` the billed
//! idle bucket — the two Pareto axes. Every field is a modeled
//! (virtual-clock) quantity, so the whole sweep replays byte-identically
//! by seed.

use std::collections::HashMap;

/// One idle cycle's retention plan, as offsets from the release time.
/// The container is warm during `[release + prewarm_s,
/// release + keep_alive_s]`; with `prewarm_s > 0` it is dead (torn down,
/// DRE evicted) before that.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct IdleWindow {
    /// seconds after release at which the sandbox is (re)provisioned;
    /// 0 = it simply stays warm from the release instant
    pub prewarm_s: f64,
    /// seconds after release at which the sandbox is reclaimed
    pub keep_alive_s: f64,
}

impl IdleWindow {
    /// Warm forever from the release instant (the pre-policy behavior).
    pub fn never_expire() -> Self {
        Self { prewarm_s: 0.0, keep_alive_s: f64::INFINITY }
    }

    /// Warm for `ttl_s` from the release instant, no prewarm.
    pub fn ttl(ttl_s: f64) -> Self {
        Self { prewarm_s: 0.0, keep_alive_s: ttl_s.max(0.0) }
    }
}

/// A keep-alive policy: pure state machine on the virtual clock. The
/// platform calls [`KeepAlivePolicy::window`] once per container release
/// and [`KeepAlivePolicy::observe_idle`] once per observed idle cycle
/// (warm hit or expiry of a previously released container). Both are
/// keyed by function name, so per-function state never bleeds across
/// pools — identical per-function event streams yield identical windows
/// regardless of how other functions' streams interleave.
pub trait KeepAlivePolicy: Send {
    /// Plan the idle cycle starting now for `function` released at
    /// virtual time `now`.
    fn window(&mut self, function: &str, now: f64) -> IdleWindow;

    /// Feed back an observed idle duration for `function` (seconds from
    /// release to the next arrival that resolved the cycle).
    fn observe_idle(&mut self, function: &str, idle_s: f64);

    /// Short policy label for reports.
    fn name(&self) -> &'static str;
}

/// Today's behavior: containers never expire. [`KeepAliveConfig`] treats
/// this as "policy disabled" — the platform takes the pre-policy fast
/// path and this impl exists for completeness/diagnostics.
#[derive(Clone, Copy, Debug, Default)]
pub struct NeverExpire;

impl KeepAlivePolicy for NeverExpire {
    fn window(&mut self, _function: &str, _now: f64) -> IdleWindow {
        IdleWindow::never_expire()
    }
    fn observe_idle(&mut self, _function: &str, _idle_s: f64) {}
    fn name(&self) -> &'static str {
        "never"
    }
}

/// Constant keep-alive after every release; no prewarm.
#[derive(Clone, Copy, Debug)]
pub struct FixedTtl {
    pub keep_alive_s: f64,
}

impl KeepAlivePolicy for FixedTtl {
    fn window(&mut self, _function: &str, _now: f64) -> IdleWindow {
        IdleWindow::ttl(self.keep_alive_s)
    }
    fn observe_idle(&mut self, _function: &str, _idle_s: f64) {}
    fn name(&self) -> &'static str {
        "ttl"
    }
}

/// Shape of the [`HybridHistogram`] policy (see the module docs).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct HybridConfig {
    /// number of histogram bins
    pub bins: usize,
    /// width of one bin in seconds
    pub bin_s: f64,
    /// idle times below this are head-out-of-bounds (shorter than the
    /// histogram can resolve)
    pub head_s: f64,
    /// observed cycles required before the histogram is trusted
    pub min_samples: u64,
    /// head/tail OOB share above which the histogram is distrusted
    pub oob_fraction: f64,
    /// in-bin coefficient of variation above which the distribution is
    /// "too dispersed" and the fixed-TTL fallback applies
    pub cv_threshold: f64,
    /// lower quantile of the in-bin mass → prewarm edge
    pub head_quantile: f64,
    /// upper quantile of the in-bin mass → keep-alive edge
    pub tail_quantile: f64,
    /// safety margin: the prewarm edge is tightened and the keep-alive
    /// edge padded by this fraction
    pub margin: f64,
    /// the fallback fixed-TTL window (no prewarm) used whenever the
    /// histogram cannot be trusted. Deliberately short: an untrusted
    /// pool pays (cheap, bounded) cold starts rather than accumulating
    /// idle-GB-s waste, and the fallback keeps feeding the histogram
    /// until it earns a learned window
    pub fallback_ttl_s: f64,
}

impl Default for HybridConfig {
    fn default() -> Self {
        Self {
            bins: 240,
            bin_s: 0.05,
            head_s: 0.01,
            min_samples: 8,
            oob_fraction: 0.5,
            cv_threshold: 1.5,
            head_quantile: 0.05,
            tail_quantile: 0.99,
            margin: 0.15,
            fallback_ttl_s: 0.1,
        }
    }
}

impl HybridConfig {
    /// Upper edge of the binnable range.
    fn range_end(&self) -> f64 {
        self.head_s + self.bins as f64 * self.bin_s
    }
}

/// Per-function idle-time statistics.
#[derive(Clone, Debug)]
struct FnHistogram {
    counts: Vec<u64>,
    in_bin: u64,
    head_oob: u64,
    tail_oob: u64,
}

impl FnHistogram {
    fn new(bins: usize) -> Self {
        Self { counts: vec![0; bins], in_bin: 0, head_oob: 0, tail_oob: 0 }
    }

    fn total(&self) -> u64 {
        self.in_bin + self.head_oob + self.tail_oob
    }
}

/// Why [`HybridHistogram::window`] chose the window it chose — surfaced
/// for tests and diagnostics.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HybridDecision {
    /// fewer than `min_samples` observed cycles: fixed-TTL fallback
    ColdStartHistory,
    /// head OOB share over `oob_fraction`: cycles too short to resolve,
    /// fixed-TTL fallback (keep warm from release)
    HeadOutOfBounds,
    /// tail OOB share over `oob_fraction`: cycles beyond the histogram
    /// range, fixed-TTL fallback (the paper hands off to a time-series
    /// model here; we document the fixed-TTL degradation instead)
    TailOutOfBounds,
    /// in-bin coefficient of variation over `cv_threshold`: distribution
    /// too dispersed to predict, fixed-TTL fallback
    TooDispersed,
    /// the histogram was trusted: quantile-derived [pre-warm, keep-alive]
    Predicted,
}

/// The "Serverless in the Wild" hybrid-histogram policy. Keeps one
/// idle-time histogram per function; see the module docs for the
/// prediction and fallback rules.
#[derive(Clone, Debug)]
pub struct HybridHistogram {
    pub cfg: HybridConfig,
    fns: HashMap<String, FnHistogram>,
}

impl HybridHistogram {
    pub fn new(cfg: HybridConfig) -> Self {
        Self { cfg, fns: HashMap::new() }
    }

    /// `(in_bin, head_oob, tail_oob)` sample counts for a function.
    pub fn sample_counts(&self, function: &str) -> (u64, u64, u64) {
        self.fns
            .get(function)
            .map(|h| (h.in_bin, h.head_oob, h.tail_oob))
            .unwrap_or((0, 0, 0))
    }

    /// The `[lo, hi)` edges of the histogram's mode bin (highest count,
    /// ties to the shortest idle), if any in-bin sample exists.
    pub fn mode_bin(&self, function: &str) -> Option<(f64, f64)> {
        let h = self.fns.get(function)?;
        if h.in_bin == 0 {
            return None;
        }
        let (i, _) = h
            .counts
            .iter()
            .enumerate()
            .max_by(|(ia, ca), (ib, cb)| ca.cmp(cb).then(ib.cmp(ia)))
            .expect("bins is non-zero");
        Some((self.bin_lo(i), self.bin_lo(i) + self.cfg.bin_s))
    }

    fn bin_lo(&self, i: usize) -> f64 {
        self.cfg.head_s + i as f64 * self.cfg.bin_s
    }

    /// Lower edge of the bin holding quantile `q` of the in-bin mass.
    fn quantile_bin(&self, h: &FnHistogram, q: f64) -> usize {
        let target = (q * h.in_bin as f64).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for (i, &c) in h.counts.iter().enumerate() {
            cum += c;
            if cum >= target {
                return i;
            }
        }
        h.counts.len() - 1
    }

    /// The window this policy would emit for `function` right now, plus
    /// the reason — the pure prediction, no state change.
    pub fn predict(&self, function: &str) -> (IdleWindow, HybridDecision) {
        let fallback = IdleWindow::ttl(self.cfg.fallback_ttl_s);
        let Some(h) = self.fns.get(function) else {
            return (fallback, HybridDecision::ColdStartHistory);
        };
        let total = h.total();
        if total < self.cfg.min_samples {
            return (fallback, HybridDecision::ColdStartHistory);
        }
        if h.head_oob as f64 > self.cfg.oob_fraction * total as f64 {
            return (fallback, HybridDecision::HeadOutOfBounds);
        }
        if h.tail_oob as f64 > self.cfg.oob_fraction * total as f64 {
            return (fallback, HybridDecision::TailOutOfBounds);
        }
        if h.in_bin == 0 {
            return (fallback, HybridDecision::ColdStartHistory);
        }
        // in-bin moments over bin centers
        let (mut sum, mut sum_sq) = (0.0f64, 0.0f64);
        for (i, &c) in h.counts.iter().enumerate() {
            let x = self.bin_lo(i) + 0.5 * self.cfg.bin_s;
            sum += c as f64 * x;
            sum_sq += c as f64 * x * x;
        }
        let mean = sum / h.in_bin as f64;
        let var = (sum_sq / h.in_bin as f64 - mean * mean).max(0.0);
        if mean > 0.0 && var.sqrt() / mean > self.cfg.cv_threshold {
            return (fallback, HybridDecision::TooDispersed);
        }
        let lo_bin = self.quantile_bin(h, self.cfg.head_quantile);
        let hi_bin = self.quantile_bin(h, self.cfg.tail_quantile);
        let (mode_lo, mode_hi) = self.mode_bin(function).expect("in_bin > 0");
        // quantile edges with margins, clamped so the window always
        // brackets the mode bin (the property the tests pin). A head
        // quantile inside the first bin is below the histogram's
        // resolution: tearing down just to re-provision milliseconds
        // later buys nothing, so keep the sandbox from the release
        // instant instead.
        let prewarm = if lo_bin == 0 {
            0.0
        } else {
            (self.bin_lo(lo_bin) * (1.0 - self.cfg.margin)).min(mode_lo).max(0.0)
        };
        let keep = ((self.bin_lo(hi_bin) + self.cfg.bin_s) * (1.0 + self.cfg.margin)).max(mode_hi);
        (IdleWindow { prewarm_s: prewarm, keep_alive_s: keep }, HybridDecision::Predicted)
    }
}

impl KeepAlivePolicy for HybridHistogram {
    fn window(&mut self, function: &str, _now: f64) -> IdleWindow {
        self.predict(function).0
    }

    fn observe_idle(&mut self, function: &str, idle_s: f64) {
        let cfg = self.cfg;
        let h = self
            .fns
            .entry(function.to_string())
            .or_insert_with(|| FnHistogram::new(cfg.bins));
        if idle_s < cfg.head_s {
            h.head_oob += 1;
        } else if idle_s >= cfg.range_end() {
            h.tail_oob += 1;
        } else {
            let i = ((idle_s - cfg.head_s) / cfg.bin_s) as usize;
            h.counts[i.min(cfg.bins - 1)] += 1;
            h.in_bin += 1;
        }
    }

    fn name(&self) -> &'static str {
        "hybrid"
    }
}

/// Which policy the platform runs — the [`crate::faas::FaasConfig`]
/// knob. `NeverExpire` (the default) means "policy disabled": the
/// platform takes the exact pre-policy code path, so default-config runs
/// stay byte-identical to the pre-policy simulator.
#[derive(Clone, Debug, PartialEq)]
pub enum KeepAliveConfig {
    NeverExpire,
    FixedTtl { keep_alive_s: f64 },
    Hybrid(HybridConfig),
}

impl Default for KeepAliveConfig {
    fn default() -> Self {
        Self::NeverExpire
    }
}

impl KeepAliveConfig {
    /// Is an actual policy (anything but `NeverExpire`) active?
    pub fn enabled(&self) -> bool {
        !matches!(self, Self::NeverExpire)
    }

    /// Instantiate the policy state; `None` when disabled.
    pub fn build(&self) -> Option<Box<dyn KeepAlivePolicy>> {
        match self {
            Self::NeverExpire => None,
            Self::FixedTtl { keep_alive_s } => {
                Some(Box::new(FixedTtl { keep_alive_s: *keep_alive_s }))
            }
            Self::Hybrid(cfg) => Some(Box::new(HybridHistogram::new(*cfg))),
        }
    }

    /// Parse a CLI/env spec: `never`, `ttl:<seconds>`, `hybrid`, or
    /// `hybrid:<fallback_ttl_s>`.
    pub fn parse(spec: &str) -> Option<Self> {
        match spec {
            "never" | "none" | "" => Some(Self::NeverExpire),
            "hybrid" => Some(Self::Hybrid(HybridConfig::default())),
            _ => {
                if let Some(t) = spec.strip_prefix("ttl:") {
                    let s = t.parse::<f64>().ok()?;
                    (s >= 0.0).then_some(Self::FixedTtl { keep_alive_s: s })
                } else if let Some(t) = spec.strip_prefix("hybrid:") {
                    let s = t.parse::<f64>().ok()?;
                    (s >= 0.0).then(|| {
                        Self::Hybrid(HybridConfig { fallback_ttl_s: s, ..Default::default() })
                    })
                } else {
                    None
                }
            }
        }
    }

    /// `SQUASH_KEEPALIVE` from the environment (unset/unparseable =
    /// `NeverExpire`) — the CI knob for running whole suites under a
    /// policy.
    pub fn from_env() -> Self {
        std::env::var("SQUASH_KEEPALIVE")
            .ok()
            .and_then(|v| Self::parse(&v))
            .unwrap_or(Self::NeverExpire)
    }

    /// Stable label for bench tables / JSON (`never`, `ttl:0.5`,
    /// `hybrid`).
    pub fn label(&self) -> String {
        match self {
            Self::NeverExpire => "never".into(),
            Self::FixedTtl { keep_alive_s } => format!("ttl:{keep_alive_s}"),
            Self::Hybrid(_) => "hybrid".into(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_ttl_and_never_expire_windows() {
        let mut never = NeverExpire;
        let w = never.window("f", 3.0);
        assert_eq!(w.prewarm_s, 0.0);
        assert!(w.keep_alive_s.is_infinite());
        let mut ttl = FixedTtl { keep_alive_s: 2.5 };
        assert_eq!(ttl.window("f", 9.0), IdleWindow { prewarm_s: 0.0, keep_alive_s: 2.5 });
        ttl.observe_idle("f", 100.0); // no-op, still fixed
        assert_eq!(ttl.window("f", 200.0).keep_alive_s, 2.5);
    }

    #[test]
    fn hybrid_falls_back_until_min_samples() {
        let cfg = HybridConfig::default();
        let mut h = HybridHistogram::new(cfg);
        let (w, why) = h.predict("f");
        assert_eq!(why, HybridDecision::ColdStartHistory);
        assert_eq!(w, IdleWindow::ttl(cfg.fallback_ttl_s));
        for _ in 0..cfg.min_samples - 1 {
            h.observe_idle("f", 1.0);
        }
        assert_eq!(h.predict("f").1, HybridDecision::ColdStartHistory);
        h.observe_idle("f", 1.0);
        assert_eq!(h.predict("f").1, HybridDecision::Predicted);
    }

    #[test]
    fn hybrid_window_brackets_the_mode() {
        let mut h = HybridHistogram::new(HybridConfig::default());
        // bimodal-ish: mass at ~0.2 s, mode at ~3.0 s
        for _ in 0..10 {
            h.observe_idle("f", 0.2);
        }
        for _ in 0..30 {
            h.observe_idle("f", 3.0);
        }
        let (w, why) = h.predict("f");
        assert_eq!(why, HybridDecision::Predicted);
        let (mode_lo, mode_hi) = h.mode_bin("f").unwrap();
        assert!(mode_lo <= 3.0 && 3.0 < mode_hi, "mode bin holds 3.0: {mode_lo}..{mode_hi}");
        assert!(w.prewarm_s <= mode_lo, "prewarm {} > mode_lo {mode_lo}", w.prewarm_s);
        assert!(w.keep_alive_s >= mode_hi, "keep {} < mode_hi {mode_hi}", w.keep_alive_s);
        assert!(w.prewarm_s < w.keep_alive_s);
    }

    #[test]
    fn hybrid_oob_counters_trigger_fallbacks() {
        let cfg = HybridConfig::default();
        // head: cycles shorter than the histogram resolves
        let mut h = HybridHistogram::new(cfg);
        for _ in 0..6 {
            h.observe_idle("f", 0.001);
        }
        for _ in 0..4 {
            h.observe_idle("f", 1.0);
        }
        assert_eq!(h.sample_counts("f"), (4, 6, 0));
        assert_eq!(h.predict("f").1, HybridDecision::HeadOutOfBounds);
        // tail: cycles beyond the histogram range
        let mut h = HybridHistogram::new(cfg);
        for _ in 0..6 {
            h.observe_idle("f", cfg.range_end() + 5.0);
        }
        for _ in 0..4 {
            h.observe_idle("f", 1.0);
        }
        assert_eq!(h.sample_counts("f"), (4, 0, 6));
        let (w, why) = h.predict("f");
        assert_eq!(why, HybridDecision::TailOutOfBounds);
        assert_eq!(w, IdleWindow::ttl(cfg.fallback_ttl_s));
    }

    #[test]
    fn hybrid_dispersion_fallback() {
        // two far-apart modes → CV above the threshold → fixed-TTL
        let cfg = HybridConfig { cv_threshold: 0.3, ..Default::default() };
        let mut h = HybridHistogram::new(cfg);
        for _ in 0..20 {
            h.observe_idle("f", 0.1);
            h.observe_idle("f", 9.0);
        }
        assert_eq!(h.predict("f").1, HybridDecision::TooDispersed);
        // a tight distribution is trusted
        let mut h = HybridHistogram::new(cfg);
        for _ in 0..20 {
            h.observe_idle("f", 1.0);
        }
        assert_eq!(h.predict("f").1, HybridDecision::Predicted);
    }

    #[test]
    fn hybrid_state_is_per_function() {
        let mut h = HybridHistogram::new(HybridConfig::default());
        for _ in 0..20 {
            h.observe_idle("a", 0.5);
            h.observe_idle("b", 4.0);
        }
        let (wa, _) = h.predict("a");
        let (wb, _) = h.predict("b");
        assert!(wa.keep_alive_s < wb.keep_alive_s, "{wa:?} vs {wb:?}");
        assert_eq!(h.sample_counts("c"), (0, 0, 0));
    }

    #[test]
    fn config_parse_round_trips() {
        assert_eq!(KeepAliveConfig::parse("never"), Some(KeepAliveConfig::NeverExpire));
        assert_eq!(
            KeepAliveConfig::parse("ttl:1.5"),
            Some(KeepAliveConfig::FixedTtl { keep_alive_s: 1.5 })
        );
        assert_eq!(
            KeepAliveConfig::parse("hybrid"),
            Some(KeepAliveConfig::Hybrid(HybridConfig::default()))
        );
        let h = KeepAliveConfig::parse("hybrid:4.0").unwrap();
        match h {
            KeepAliveConfig::Hybrid(c) => assert_eq!(c.fallback_ttl_s, 4.0),
            other => panic!("expected hybrid, got {other:?}"),
        }
        assert_eq!(KeepAliveConfig::parse("bogus"), None);
        assert_eq!(KeepAliveConfig::parse("ttl:-1"), None);
        assert!(!KeepAliveConfig::NeverExpire.enabled());
        assert!(KeepAliveConfig::FixedTtl { keep_alive_s: 0.5 }.enabled());
        assert_eq!(KeepAliveConfig::FixedTtl { keep_alive_s: 0.5 }.label(), "ttl:0.5");
        assert!(KeepAliveConfig::NeverExpire.build().is_none());
        assert_eq!(KeepAliveConfig::parse("hybrid").unwrap().build().unwrap().name(), "hybrid");
    }
}
